"""The FUSEE client: SNAPSHOT replication (Alg. 1+2+4), two-level allocation
(§4.4), embedded operation log (§4.5), adaptive index cache (§4.6), and the
four KV-op workflows of Fig. 9.

Each public ``op_*`` method returns a *generator* that yields
``events.Phase`` / ``events.MasterCall`` objects and finally returns an
``events.OpResult``.  The scheduler in sim.py drives these generators,
interleaving verbs across clients; nothing here touches the pool directly
except through yielded verbs — exactly the one-sided-RDMA discipline of the
paper.

RTT accounting follows Fig. 9: every yielded non-background phase is one
doorbell-batched round trip.  The conflict-free fast path is
INSERT/UPDATE/DELETE = 4 RTTs, SEARCH = 1-2 RTTs.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

from . import layout as L
from . import ordered
from . import race
from .events import (CAUSE_CAS_LOST, CAUSE_FP_COLLISION, CAUSE_FULL,
                     CAUSE_LOSE_POLL, CAUSE_NONE, CAUSE_STALE_EPOCH,
                     EXISTS, FULL, NOT_FOUND, OK, MasterCall, OpResult, Phase,
                     Verb)
from .heap import FIRST_DATA_REGION, INDEX_REGION, META_REGION, \
    META_WORDS_PER_CLIENT, DMConfig, DMPool
from .rng import SimRng

# Sentinel the master writes into an old_value field it committed on a
# client's behalf (§A.4.3); any non-zero value with a valid CRC means
# "committed".  1 can never be a real slot value (fp=0 is reserved).
MASTER_COMMIT_MARK = 1

R1, R2, R3 = "Rule1", "Rule2", "Rule3"
LOSE, FINISH, FAILV = "LOSE", "FINISH", "FAIL"

# Bounded-retry cap for index races (stale candidates, lost empty-slot CAS
# rounds).  Exists only to turn a livelock into a typed FULL; it must
# comfortably exceed the worst per-bucket concurrency — a 1024-client fleet
# tick can legally pile a whole bucket's load onto one empty slot at once.
MAX_OP_RETRIES = 64

# A SNAPSHOT loser polls the primary waiting for the winner's commit (Alg 1
# lines 17-22).  If the winner crashed mid-commit the slot never moves, so
# after this many polls the loser escalates to the master's fail_query
# (Alg 4 / §A.4.3), which arbitrates the stalled round.  Generous enough
# that a merely slow-scheduled winner almost always commits first.
MAX_LOSE_POLLS = 48

# TEST-ONLY: when True, op_insert acks OK after LOSING an empty-slot CAS
# round instead of retrying — the historical PR-3 lost-write bug (the
# winner may have inserted a *different* key, so the acknowledged write is
# nowhere in the index).  Exists solely so regression tests can
# re-introduce the bug and assert the race detector
# (repro.analysis.races, rule ``lost_cas_ack``) flags it.
UNSAFE_ACK_LOST_EMPTY_CAS = False

# TEST-ONLY: when True, the upsert retry path frees the "overwritten" object
# even when it is the op's OWN object — the churn-cutover acked-write-loss
# bug (storm seed 7): a retry that crossed a lease-epoch bump re-reads the
# index, finds its own half-installed slot value (propagated to every
# replica by the cutover's Alg-3 adopt-backup repair), treats it as the
# old value, and — since v_old's pointer equals v_new's — the post-ack
# ``bg:free_old`` phase frees + invalidates the very object the index now
# references (use-after-free; the acked write is lost when the block is
# reused).  The fix skips reclamation whenever the displaced slot value
# points at the op's own object.  Exists solely so the model checker
# (repro.analysis.explore) and regression tests can re-introduce the bug
# and assert it is found + minimized.
UNSAFE_FREE_OWN_ON_RETRY = False

# TEST-ONLY: when True, a SNAPSHOT round that observes the primary moved
# off ``v_old`` concludes LOSE/FINISH *without first checking whether the
# primary moved to its OWN ``v_new``* — the storm-seeds-8/15 loser-reset
# bug.  The master can land a "loser's" value on its behalf: Alg-3
# recovery (``Master._repair_index_region``) adopts the first alive
# backup on divergence, and ``Master.fail_query`` adopts a backup
# majority — both then commit the embedded log of whatever value they
# installed.  A client whose backup-CAS residue was adopted that way is
# *the committed winner*, but its LOSE poll (Alg 1 lines 17-22) only
# tested ``primary != v_old``; under this flag it then resets its own
# used bit and returns LOSE, leaving the index slot referencing a used=0
# object (heapcheck: "slot survived a loser reset", CRC/fp failures,
# key-in-two-slots once the reset object is reclaimed and reused).  The
# fix treats primary==v_new as MASTER_WIN — every path that can install
# v_new on our behalf also commits our embedded log, so acking is safe
# and the used bit must stay set.  Exists solely so the model checker
# (repro.analysis.explore, scope ``loser_reset``) and regression tests
# can re-introduce the bug and assert it is found + minimized.
UNSAFE_LOSE_ON_OWN_COMMIT = False


def evaluate_rules_pure(v_list: List[Optional[int]], v_new: int):
    """Pure part of Alg. 2 (no Rule-3 primary check).  ``None`` = FAIL.

    Returns one of R1 / R2 / LOSE / FAILV / 'NEED_CHECK' (Rule-3 candidate).
    """
    if any(v is None for v in v_list):
        return FAILV
    if not v_list:  # r == 1: no backups; degenerate fast path handled upstream
        return R1
    vals = [int(v) for v in v_list]
    counts: Dict[int, int] = {}
    for v in vals:
        counts[v] = counts.get(v, 0) + 1
    v_maj = max(counts, key=lambda k: (counts[k], -k))
    cnt = counts[v_maj]
    n = len(vals)
    if cnt == n:
        return R1 if v_maj == int(v_new) else LOSE
    if 2 * cnt > n:
        return R2 if v_maj == int(v_new) else LOSE
    if int(v_new) not in vals:
        return LOSE
    return "NEED_CHECK"


@dataclass
class CacheEntry:
    slot_off: int
    slot_val: int
    access: int = 0
    invalid: int = 0
    # The key's index shard region and its placement-directory version at
    # fill time (§4.6 cache + elasticity): after the shard migrates or is
    # re-homed by recovery, the entry is no longer trusted for the 1-RTT
    # fast path until a full SEARCH revalidates it under the new
    # placement.  ``region`` is cached so the API layer's shadow-probe
    # eligibility filter never re-hashes keys to shards.
    region: int = 0
    shard_ver: int = 0

    @property
    def invalid_ratio(self) -> float:
        return self.invalid / max(1, self.access)


@dataclass
class SlabClass:
    free: deque = field(default_factory=deque)   # FIFO of ptrs (§4.5 ordering)
    last_alloc: int = 0                          # prev_ptr for the next alloc
    head_written: bool = False
    blocks: List[Tuple[int, int]] = field(default_factory=list)  # (region, blk)


class FuseeClient:
    def __init__(self, cid: int, pool: DMPool, *,
                 enable_cache: bool = True,
                 cache_threshold: float = 0.5,
                 replication_mode: str = "snapshot",  # 'snapshot' | 'cr'
                 seed: int = 0,
                 rng: Optional[np.random.Generator] = None):
        self.cid = cid
        self.pool = pool
        self.cfg: DMConfig = pool.cfg
        self.enable_cache = enable_cache
        self.cache_threshold = cache_threshold
        self.replication_mode = replication_mode
        # per-client protocol-jitter substream: callers (store.py) thread
        # the run's SimRng root; standalone construction derives the same
        # named substream from the seed (deterministic-replay contract)
        self.rng = rng if rng is not None \
            else SimRng(seed).stream(f"client.{cid}")
        self.slab: Dict[int, SlabClass] = {}
        self.cache: Dict[int, CacheEntry] = {}
        self.epoch = pool.epoch
        self._alloc_mn_rr = cid % self.cfg.num_mns
        # Set by the master / scheduler on membership changes (lease expiry).
        self.notified_prepare = False
        # deferred background frees: list of (region, block_idx, obj_idx)
        self._pending_resets: List[Tuple[int, int]] = []
        # ordered-keydir fence cache: leaf_id -> low key (append-only
        # facts — a leaf's low never changes and leaves never merge; see
        # core/ordered.py).  Empty until the first scan/ensure bootstraps.
        self.ord_fences: Dict[int, int] = {}
        self.ord_full_drops = 0   # inserts whose keydir entry hit ORD FULL
        self.crashed = False

    # ------------------------------------------------------------------ util
    @property
    def r(self) -> int:
        return len(self.pool.placement[INDEX_REGION])

    def _index_region(self, key: int) -> int:
        """Shard routing: the index region holding this key's buckets (a
        pure key hash over the S shard regions; S=1 -> INDEX_REGION)."""
        return self.pool.index_region_of(key)

    def _shard_ver(self, region: int) -> int:
        return self.pool.directory.version(region)

    def _cache_fresh(self, ce: CacheEntry, region: int) -> bool:
        """A cache entry is trusted for the 1-RTT fast path only while its
        shard's placement version is unchanged (keyed-by-shard-epoch cache
        contract; a migrated shard forces one full SEARCH revalidation)."""
        return ce.shard_ver == self._shard_ver(region)

    def _slot_verb_read_primary(self, region: int, off: int) -> Verb:
        return Verb("read", region=region, replica=0, off=off, n=1)

    def _obj_region_replicas(self, region: int) -> int:
        return len(self.pool.placement[region])

    def _ptr_of(self, region: int, off: int) -> int:
        return L.pack_ptr(region, off)

    def _read_obj_verb(self, ptr: int, sc: int, replica: int = 0) -> Verb:
        return Verb("read", region=L.ptr_region(ptr), replica=replica,
                    off=L.ptr_offset(ptr), n=L.size_class_words(sc))

    # ---------------------------------------------------------- slab (level 2)
    def _sc_state(self, sc: int) -> SlabClass:
        if sc not in self.slab:
            self.slab[sc] = SlabClass()
        return self.slab[sc]

    def _ensure_free(self, sc: int):
        """Keep >=2 free objects so the pre-positioned next_ptr always exists."""
        if self.cfg.block_payload_words // L.size_class_words(sc) == 0:
            # the object class exceeds a block's payload: no grant can ever
            # yield an object — typed FULL, and no block is leaked trying
            return FULL
        st = self._sc_state(sc)
        attempts = 0
        cause = CAUSE_NONE
        while len(st.free) < 2:
            mn = self._alloc_mn_rr % self.cfg.num_mns
            self._alloc_mn_rr += 1
            attempts += 1
            if attempts > 2 * self.cfg.num_mns:
                return FULL
            if not self.pool.mns[mn].alive:
                continue
            res = yield Phase([Verb("alloc", mn=mn)], label="alloc",
                              cause=cause)
            if res[0] is None:
                cause = CAUSE_FULL   # failed grant: re-asking under pressure
                continue
            region, blk = res[0]
            base = self.pool.block_base(blk)
            scw = L.size_class_words(sc)
            n_objs = self.cfg.block_payload_words // scw
            for i in range(n_objs):
                st.free.append(self._ptr_of(region, base + i * scw))
            st.blocks.append((region, blk))
            if not st.head_written:
                # §4.5: store the per-size-class list head on MNs at init time
                # (first block grant).  Head = first object to be allocated.
                head_ptr = st.free[0]
                off = self.cid * META_WORDS_PER_CLIENT + sc
                verbs = [Verb("write", region=META_REGION, replica=i, off=off,
                              words=[head_ptr])
                         for i in range(len(self.pool.placement[META_REGION]))]
                yield Phase(verbs, label="write_list_head")
                st.head_written = True
        return OK

    def _take_obj(self, sc: int) -> Tuple[int, int, int]:
        """Pop the FIFO head. Returns (ptr, next_ptr, prev_ptr)."""
        st = self._sc_state(sc)
        ptr = st.free.popleft()
        next_ptr = st.free[0] if st.free else 0
        prev_ptr = st.last_alloc
        st.last_alloc = ptr
        return ptr, next_ptr, prev_ptr

    def _write_obj_verbs(self, ptr: int, words) -> List[Verb]:
        region = L.ptr_region(ptr)
        off = L.ptr_offset(ptr)
        return [Verb("write", region=region, replica=i, off=off, words=words)
                for i in range(self._obj_region_replicas(region))]

    def _free_obj_verbs(self, slot_val: int) -> List[Verb]:
        """FAA the free bit of the object referenced by a slot value (§4.4)."""
        ptr = L.slot_ptr(slot_val)
        region, off = L.ptr_region(ptr), L.ptr_offset(ptr)
        cfg = self.cfg
        blk = (off - cfg.bat_words) // cfg.block_words
        base = self.pool.block_base(blk)
        obj_idx = (off - base) // L.MIN_OBJ_WORDS  # bit index at min-class granularity
        woff = self.pool.bitmap_base(blk) + obj_idx // 64
        delta = 1 << (obj_idx % 64)
        return [Verb("faa", region=region, replica=i, off=woff, delta=delta)
                for i in range(self._obj_region_replicas(region))]

    def _reset_used_verbs(self, ptr: int, sc: int, prev_ptr: int) -> List[Verb]:
        tail = int(L.pack_log_tail(prev_ptr, used=False))
        off = L.ptr_offset(ptr) + L.size_class_words(sc) - 1
        region = L.ptr_region(ptr)
        return [Verb("write", region=region, replica=i, off=off, words=[tail])
                for i in range(self._obj_region_replicas(region))]

    def _mark_invalid_verbs(self, slot_val: int) -> List[Verb]:
        """Set the invalidation bit of the *old* KV pair (§4.6 cache coherence).

        Uses FAA on the tail word; the invalid bit is set at most once (by the
        unique round winner), so FAA == set-bit.
        """
        ptr = L.slot_ptr(slot_val)
        sc = L.slot_size_class(slot_val)
        off = L.ptr_offset(ptr) + L.size_class_words(sc) - 1
        region = L.ptr_region(ptr)
        return [Verb("faa", region=region, replica=i, off=off, delta=L.INVALID_BIT)
                for i in range(self._obj_region_replicas(region))]

    def _bg_cleanup(self, verbs: List[Verb], label: str):
        """Issue background cleanup obligations (free-bit FAA / cache
        invalidation / used-bit reset) and re-issue any that bounced.

        A verb that returns None was NOT executed (lease-epoch bounce or
        dead MN) — dropping it leaks the object: used bit set, no index
        reference, free-list push lost.  Re-issuing the same Verb instance
        is safe because the scheduler re-stamps its epoch on enqueue and a
        None result guarantees the side effect never landed (re-building
        FAA verbs would NOT be safe — a landed FAA re-issued flips the bit
        back).

        Bounced verbs are re-aimed by the MN *identity* they originally
        targeted, not their replica index: an MN-crash failover renumbers
        the surviving copies, so "replica 1" of a 2-replica region becomes
        replica 0 of a 1-replica region while pointing at the exact same
        memory.  Filtering by index there discards a still-owed obligation
        against live memory and leaks the object on the new primary (found
        by the model checker's stale_epoch scope).  A verb whose target MN
        no longer hosts the region is moot (the copy's memory died with
        the MN) or migrated away — either way it falls to the owner-side
        reclaim scan (§4.4).  Bounded best effort: after MAX_OP_RETRIES
        rounds the remainder is likewise left to the reclaim scan.
        """
        def _target_mn(v: Verb) -> int:
            reps = self.pool.placement.get(v.region, ())
            return reps[v.replica] if v.replica < len(reps) else -1

        pending = [(v, _target_mn(v)) for v in verbs]
        attempts = 0
        while pending and attempts <= MAX_OP_RETRIES:
            res = yield Phase([v for v, _ in pending], label=label,
                              background=True,
                              cause=CAUSE_STALE_EPOCH if attempts
                              else CAUSE_NONE)
            nxt = []
            for (v, mn), r in zip(pending, res):
                if r is not None:
                    continue
                reps = self.pool.placement.get(v.region, ())
                if mn in reps:  # copy survived, possibly renumbered
                    v.replica = list(reps).index(mn)
                    nxt.append((v, mn))
            pending = nxt
            attempts += 1

    # ------------------------------------------------- SNAPSHOT WRITE (Alg 1)
    def _snapshot_write(self, region: int, slot_off: int, v_old: int,
                        v_new: int, obj_ptr: int, obj_sc: int, prev_ptr: int,
                        cause: str = CAUSE_NONE):
        """Returns (status, rule, committed_value_now_in_primary_or_None).

        ``region`` is the key's index shard (shard routing); the whole
        round — backup broadcast, rule 3 check, primary CAS, fail path —
        addresses that shard's replicas.  ``obj_ptr/obj_sc/prev_ptr``
        identify this writer's object so the commit (phase 3) and loser
        used-bit reset target the embedded log.  ``cause`` carries the
        op-level retry cause into this round's opening phase so the span
        profiler attributes re-entered SNAPSHOT rounds to what forced them.
        """
        if self.replication_mode == "cr":
            return (yield from self._cr_write(region, slot_off, v_old, v_new,
                                              cause))
        r = len(self.pool.placement[region])   # this shard's replica count
        extra = 0
        if r == 1:
            # Degenerate: no backups; CAS primary directly; the log commit is
            # skipped (§6.1, single-index-replica comparison mode).
            res = yield Phase([Verb("cas", region=region, replica=0,
                                    off=slot_off, exp=v_old, new=v_new)],
                              label="4:cas_primary", cause=cause)
            if res[0] is None:
                return (yield from self._fail_path(region, slot_off, v_old,
                                                   v_new, obj_ptr, obj_sc,
                                                   prev_ptr,
                                                   cause=CAUSE_STALE_EPOCH))
            if int(res[0]) == int(v_old):
                return OK, R1, v_new
            if int(res[0]) == int(v_new) and not UNSAFE_LOSE_ON_OWN_COMMIT:
                # the primary already holds OUR value: the master installed
                # it on our behalf (fail_query arbitration of an earlier
                # bounced round) and committed our log — we are the winner
                return OK, "MASTER_WIN", v_new
            # lost the race; linearize just before the winner
            yield Phase(self._reset_used_verbs(obj_ptr, obj_sc, prev_ptr),
                        label="loser_reset", cause=CAUSE_CAS_LOST)
            return OK, LOSE, int(res[0])

        # Phase 2: broadcast CAS to all backups (Alg 1, line 7)
        res = yield Phase([Verb("cas", region=region, replica=i,
                                off=slot_off, exp=v_old, new=v_new)
                           for i in range(1, r)], label="2:cas_backups",
                          cause=cause)
        v_list = [None if v is None else
                  (int(v_new) if int(v) == int(v_old) else int(v))
                  for v in res]
        win = evaluate_rules_pure(v_list, v_new)
        if win == "NEED_CHECK":
            # Rule 3 pre-check (Alg 2, line 12): has the primary moved?
            chk = yield Phase([self._slot_verb_read_primary(region, slot_off)],
                              label="rule3_check")
            if chk[0] is None:
                win = FAILV
            elif int(chk[0][0]) == int(v_new) \
                    and not UNSAFE_LOSE_ON_OWN_COMMIT:
                # the primary moved to OUR value: the master's adopt-backup
                # repair (Alg-3 recovery or fail_query) installed our
                # backup-CAS residue and committed our log — concluding
                # FINISH here would reset the used bit of the very object
                # the index now references (the seeds-8/15 bug)
                return OK, "MASTER_WIN", v_new
            elif int(chk[0][0]) != int(v_old):
                win = FINISH
            elif min(v_list) == int(v_new):
                win = R3
            else:
                win = LOSE

        if win == FAILV:
            return (yield from self._fail_path(region, slot_off, v_old, v_new,
                                               obj_ptr, obj_sc, prev_ptr,
                                               cause=CAUSE_STALE_EPOCH))

        if win in (R1, R2, R3):
            # Phase 3: commit the embedded log (write old_value + CRC into our
            # object, all replicas) and, for Rule 2/3, repair divergent
            # backups in the same doorbell batch.
            verbs = self._commit_log_verbs(obj_ptr, obj_sc, v_old)
            nlog = len(verbs)
            if win in (R2, R3):
                verbs += [Verb("cas", region=region, replica=i + 1,
                               off=slot_off, exp=v_list[i], new=v_new)
                          for i in range(r - 1) if v_list[i] != int(v_new)]
            res3 = yield Phase(verbs, label="3:commit+fix")
            bad = any(v is None for v in res3)
            if not bad:
                for v, fix in zip(res3[nlog:], verbs[nlog:]):
                    if int(v) not in (int(fix.exp), int(v_new)):
                        bad = True   # backup moved to a THIRD value mid-fix
                        break
            if bad:
                # A commit/fix verb bounced on a lease-epoch change, or a
                # divergent backup moved again under the repair: acking now
                # could leave a backup newer than the primary (the Alg-3
                # invariant) or our round half-installed — escalate to the
                # master's arbitration (Alg 4) instead.
                return (yield from self._fail_path(region, slot_off, v_old,
                                                   v_new, obj_ptr, obj_sc,
                                                   prev_ptr,
                                                   cause=CAUSE_STALE_EPOCH))
            res = yield Phase([Verb("cas", region=region, replica=0,
                                    off=slot_off, exp=v_old, new=v_new)],
                              label="4:cas_primary")
            if res[0] is None:
                return (yield from self._fail_path(region, slot_off, v_old,
                                                   v_new, obj_ptr, obj_sc,
                                                   prev_ptr,
                                                   cause=CAUSE_STALE_EPOCH))
            if int(res[0]) != int(v_old):
                # The primary moved after our rule check: a concurrent round
                # (possibly for a DIFFERENT key colliding on this slot)
                # committed first, so we did NOT win — acking here is the
                # seed-13 lost-write hole.  Let the master arbitrate: it
                # decides v_new (win), v_old (retry), or the other round's
                # value (lose; op_insert's empty-slot guard re-runs us).
                return (yield from self._fail_path(region, slot_off, v_old,
                                                   v_new, obj_ptr, obj_sc,
                                                   prev_ptr,
                                                   cause=CAUSE_CAS_LOST))
            return OK, win, v_new

        if win == FINISH:
            yield Phase(self._reset_used_verbs(obj_ptr, obj_sc, prev_ptr),
                        label="loser_reset", cause=CAUSE_CAS_LOST)
            return OK, FINISH, None

        # LOSE: poll the primary until the winner commits (Alg 1, lines 17-22)
        polls = 0
        while True:
            if self.notified_prepare or polls >= MAX_LOSE_POLLS:
                # membership change, or the winner is taking suspiciously
                # long (crashed mid-commit?): escalate to the master
                return (yield from self._fail_path(region, slot_off, v_old,
                                                   v_new, obj_ptr, obj_sc,
                                                   prev_ptr,
                                                   cause=CAUSE_LOSE_POLL))
            polls += 1
            chk = yield Phase([self._slot_verb_read_primary(region, slot_off)],
                              label="lose_poll", cause=CAUSE_LOSE_POLL)
            if chk[0] is None:
                return (yield from self._fail_path(region, slot_off, v_old,
                                                   v_new, obj_ptr, obj_sc,
                                                   prev_ptr,
                                                   cause=CAUSE_STALE_EPOCH))
            if int(chk[0][0]) != int(v_old):
                break
        if int(chk[0][0]) == int(v_new) and not UNSAFE_LOSE_ON_OWN_COMMIT:
            # the slot moved to OUR value while we were polling: an MN
            # crash mid-round let Alg-3 recovery adopt our backup-CAS
            # residue (``_repair_index_region`` takes the first alive
            # backup) and commit our embedded log.  We are the committed
            # winner — resetting the used bit now would leave the index
            # slot referencing a dead object (storm seeds 8/15).
            return OK, "MASTER_WIN", v_new
        # reset our used bit before returning so recovery never redoes a
        # returned (lost) op — required for linearizability under redo (§5.3).
        yield Phase(self._reset_used_verbs(obj_ptr, obj_sc, prev_ptr),
                    label="loser_reset", cause=CAUSE_CAS_LOST)
        return OK, LOSE, int(chk[0][0])

    def _cr_write(self, region: int, slot_off: int, v_old: int, v_new: int,
                  cause: str = CAUSE_NONE):
        """FUSEE-CR baseline (§6.1): sequentially CAS every replica.

        One CAS per RTT, primary last — latency grows linearly with r.
        """
        r = len(self.pool.placement[region])
        for i in range(r - 1, -1, -1):
            while True:
                res = yield Phase([Verb("cas", region=region, replica=i,
                                        off=slot_off, exp=v_old, new=v_new)],
                                  label=f"cr:cas_{i}", cause=cause)
                if res[0] is None:
                    return FAILV, None, None
                old = int(res[0])
                if old == int(v_old) or old == int(v_new):
                    break
                cause = CAUSE_CAS_LOST   # lost this replica's round: re-CAS
                if i == r - 1:
                    # lost on the first replica: adopt last-writer-wins by
                    # retrying on the new value
                    v_old = old
                else:
                    v_old = old
            # continue to next replica with the same expected value
        return OK, "CR", v_new

    def _commit_log_verbs(self, obj_ptr: int, obj_sc: int, v_old: int) -> List[Verb]:
        region = L.ptr_region(obj_ptr)
        off = L.ptr_offset(obj_ptr)
        n = L.size_class_words(obj_sc)
        crc = L.crc8([int(v_old)])
        # rewrite w[-3] (old_value) and w[-2] (next|op|crc): we must preserve
        # next/op which we know locally; reconstructed by the op wrapper.
        old_w = int(np.uint64(int(v_old) & 0xFFFF_FFFF_FFFF_FFFF))
        mid = self._pending_mid  # set by the op before calling snapshot_write
        mid_new = int(L.pack_log_mid(L.log_mid_next(mid), L.log_mid_opcode(mid), crc))
        verbs = [Verb("write", region=region, replica=i, off=off + n - 3,
                      words=[old_w, mid_new])
                 for i in range(self._obj_region_replicas(region))]
        return verbs

    # ------------------------------------------------------- failure path
    def _fail_path(self, region: int, slot_off: int, v_old: int, v_new: int,
                   obj_ptr: int, obj_sc: int, prev_ptr: int,
                   cause: str = CAUSE_STALE_EPOCH):
        """Alg 4 lines 34-38: ask the master, retry if our write is too new.

        ``cause`` records WHY the round escalated (bounced verb vs lost
        CAS vs stalled LOSE poll) so the wait-master stall beats are
        attributed to the triggering event, not lumped together.
        """
        while True:
            ans = yield MasterCall("fail_query", payload=dict(
                region=region, slot_off=slot_off, v_old=v_old, v_new=v_new,
                cid=self.cid))
            if ans is None:
                # master has not yet detected/recovered; wait a beat
                yield Phase([], label="wait_master", cause=cause)
                continue
            self.epoch = self.pool.epoch
            self.notified_prepare = False
            v_dec = int(ans)
            if v_dec == int(v_new):
                return OK, "MASTER_WIN", v_new
            if v_dec == int(v_old):
                # our value was not applied and the decided value is stale:
                # retry the write from scratch (Alg 4 line 37-38)
                return "RETRY", None, v_dec
            # someone else's newer value was committed; we linearize before it
            yield Phase(self._reset_used_verbs(obj_ptr, obj_sc, prev_ptr),
                        label="loser_reset", cause=CAUSE_CAS_LOST)
            return OK, "MASTER_LOSE", v_dec

    # ------------------------------------------------------------ index read
    def _read_index_for(self, key: int, extra_verbs: List[Verb],
                        cause: str = CAUSE_NONE):
        """Phase 1 helper: read both candidate buckets of the key's index
        shard (+ any op-specific verbs folded into the same doorbell
        batch).  Shard routing happens here for every op's index read.
        ``cause`` marks re-entered rounds (op-level retry loops).

        Returns (bucket_words, base_offs, extra_results).
        """
        cfg = self.cfg
        region = self._index_region(key)
        b1, b2 = race.bucket_pair(key, cfg.index_buckets)
        o1 = race.bucket_off(b1, cfg.slots_per_bucket)
        o2 = race.bucket_off(b2, cfg.slots_per_bucket)
        verbs = [Verb("read", region=region, replica=0, off=o1,
                      n=cfg.slots_per_bucket),
                 Verb("read", region=region, replica=0, off=o2,
                      n=cfg.slots_per_bucket)] + extra_verbs
        res = yield Phase(verbs, label="1:read_index", cause=cause)
        if res[0] is None or res[1] is None:
            return None, None, res[2:]
        return ([list(res[0]), list(res[1])], [o1, o2], res[2:])

    def _locate(self, key: int, buckets, base_offs):
        """Find (slot_off, slot_val) candidates whose fp matches key."""
        fp = L.fingerprint(key)
        cands = []
        for words, base in zip(buckets, base_offs):
            cands += race.find_matches(words, base, fp)
        return cands

    def _verify_candidates(self, key: int, cands, cause: str = CAUSE_NONE):
        """Read all fp-matching KV objects in one batch; return the match.

        Returns (slot_off, slot_val, obj, stale).  ``stale`` means some
        candidate's fingerprint matched but the object did not verify
        (invalidated / freed / overwritten concurrently) — the index should
        be re-read rather than concluding the key is absent (RACE §data-
        access integrity check: key + CRC validate every read).
        """
        if not cands:
            return None, None, None, False
        verbs = [self._read_obj_verb(L.slot_ptr(v), L.slot_size_class(v))
                 for (_, v) in cands]
        res = yield Phase(verbs, label="2:read_kv", cause=cause)
        stale = False
        for (off_v, raw) in zip(cands, res):
            if raw is None:
                stale = True
                continue
            obj = L.parse_object(list(raw))
            if obj["key"] == key and obj["used"] and not obj["invalid"] and obj["crc_ok"]:
                return off_v[0], off_v[1], obj, False
            if obj["key"] != key and obj["used"] and obj["crc_ok"]:
                # a *different* key's live object behind a matching 8-bit
                # fingerprint: a permanent collision, not staleness —
                # retrying the index read would spin forever (at fleet key
                # counts fp collisions are routine, and treating them as
                # stale starves the op into a spurious FULL)
                continue
            stale = True  # mid-write / freed / invalidated: re-read helps
        return None, None, None, stale

    # ------------------------------------------------------------- SEARCH
    def op_search(self, key: int):
        rtts = [0]
        region = self._index_region(key)
        ce = self.cache.get(key) if self.enable_cache else None
        use_cache = (ce is not None
                     and ce.invalid_ratio <= self.cache_threshold
                     and self._cache_fresh(ce, region))
        if ce is not None:
            ce.access += 1
        obs = self.pool._obs
        if obs is not None:
            obs.heat_key64(key)      # buffered; hashed vectorized at flush
        if use_cache:
            # 1 RTT fast path: read the cached slot + the cached KV in parallel
            sv = ce.slot_val
            verbs = [Verb("read", region=region, replica=0,
                          off=ce.slot_off, n=1),
                     self._read_obj_verb(L.slot_ptr(sv), L.slot_size_class(sv))]
            res = yield Phase(verbs, label="1:cached_read")
            if res[0] is not None and res[1] is not None:
                cur_slot = int(res[0][0])
                obj = L.parse_object(list(res[1]))
                if (cur_slot == int(sv) and obj["key"] == key and obj["used"]
                        and not obj["invalid"] and obj["crc_ok"]):
                    return OpResult(OK, value=obj["value"], rtts=1)
                ce.invalid += 1
                if cur_slot != 0 and L.slot_fp(cur_slot) == L.fingerprint(key):
                    # slot moved: fetch the new object (read amplification!)
                    res2 = yield Phase([self._read_obj_verb(
                        L.slot_ptr(cur_slot), L.slot_size_class(cur_slot))],
                        label="2:read_kv")
                    if res2[0] is not None:
                        obj2 = L.parse_object(list(res2[0]))
                        if obj2["key"] == key and obj2["used"] and obj2["crc_ok"]:
                            ce.slot_val = cur_slot
                            return OpResult(OK, value=obj2["value"], rtts=2)
            # fall through to the miss path
        cause = CAUSE_NONE
        for _attempt in range(8):
            out = yield from self._read_index_for(key, [], cause=cause)
            buckets, base_offs, _ = out
            if buckets is None:
                return (yield from self._search_degraded(key))
            cands = self._locate(key, buckets, base_offs)
            slot_off, slot_val, obj, stale = yield from self._verify_candidates(
                key, cands, cause=cause)
            cause = CAUSE_FP_COLLISION   # only stale re-reads loop back here
            if obj is not None:
                if self.enable_cache:
                    e = self.cache.setdefault(key, CacheEntry(slot_off, slot_val))
                    e.slot_off, e.slot_val = slot_off, slot_val
                    e.region, e.shard_ver = region, self._shard_ver(region)
                return OpResult(OK, value=obj["value"], rtts=2)
            if not stale:
                return OpResult(NOT_FOUND, rtts=2)
        return OpResult(NOT_FOUND, rtts=2)

    def op_search_batch(self, items):
        """Vectorized cache-resident SEARCH: one doorbell batch reads the
        cached slot + cached KV object of *every* key in ``items`` — the
        whole batch costs 1 RTT instead of 1-2 RTTs per key.

        ``items`` is a list of ``(key, slot_off, slot_val)`` picked by the
        API layer (core/api.py) from this client's index cache via the
        race_lookup kernel.  Per-key validation is identical to the cached
        fast path of ``op_search``: the slot must still hold the cached
        value and the object must verify (key + used + !invalid + CRC).
        Keys that fail validation are reported as misses — the caller
        falls back to individual ``op_search`` ops for them.

        Returns ``OpResult(OK, value=[(status|None, value|None), ...])``
        aligned with ``items``; ``None`` status = fall back.
        """
        verbs = []
        for (key, slot_off, slot_val) in items:
            verbs.append(Verb("read", region=self._index_region(key),
                              replica=0, off=slot_off, n=1))
            verbs.append(self._read_obj_verb(L.slot_ptr(slot_val),
                                             L.slot_size_class(slot_val)))
        res = yield Phase(verbs, label="1:batch_cached_read")
        out = []
        for i, (key, slot_off, slot_val) in enumerate(items):
            ce = self.cache.get(key)
            if ce is not None:
                ce.access += 1
            slot_raw, kv_raw = res[2 * i], res[2 * i + 1]
            hit = False
            if slot_raw is not None and kv_raw is not None:
                cur_slot = int(slot_raw[0])
                obj = L.parse_object(list(kv_raw))
                if (cur_slot == int(slot_val) and obj["key"] == key
                        and obj["used"] and not obj["invalid"]
                        and obj["crc_ok"]):
                    out.append((OK, obj["value"]))
                    hit = True
            if not hit:
                if ce is not None:
                    ce.invalid += 1
                out.append((None, None))
        return OpResult(OK, value=out, rtts=1)

    def _search_degraded(self, key: int):
        """§5.2 READ when the primary read failed: read all replicas of
        the key's shard; if they agree, use that value; otherwise ask the
        master.

        Every replica returning FAIL does NOT mean the key is absent — it
        almost always means the lease epoch moved mid-flight (MN recovery
        or a migration cutover committed between issue and execution, and
        several can land back-to-back during a scale-out), so the phase
        is re-issued under the committed epoch rather than concluding
        NOT_FOUND for a key that exists."""
        cfg = self.cfg
        region = self._index_region(key)
        b1, b2 = race.bucket_pair(key, cfg.index_buckets)
        offs = [race.bucket_off(b1, cfg.slots_per_bucket),
                race.bucket_off(b2, cfg.slots_per_bucket)]
        attempts = 0
        cause = CAUSE_STALE_EPOCH   # entered because the primary read failed
        while True:
            attempts += 1
            r = len(self.pool.placement[region])  # re-read: may change
            verbs = [Verb("read", region=region, replica=i, off=o,
                          n=cfg.slots_per_bucket)
                     for o in offs for i in range(r)]
            res = yield Phase(verbs, label="deg:read_all", cause=cause)
            per_bucket, bounced = {}, False
            for j, o in enumerate(offs):
                reps = [res[j * r + i] for i in range(r)]
                alive = [list(x) for x in reps if x is not None]
                if not alive:
                    bounced = True
                    break
                if all(a == alive[0] for a in alive):
                    per_bucket[o] = alive[0]
                else:
                    ans = yield MasterCall("bucket_query",
                                           payload=dict(off=o, region=region))
                    per_bucket[o] = list(ans)
            if bounced:
                if attempts > MAX_OP_RETRIES:
                    # genuinely unreachable (> r-1 failures): best effort
                    return OpResult(NOT_FOUND, rtts=2)
                yield MasterCall("fail_report", payload=dict(cid=self.cid))
                yield Phase([], label="wait_membership",
                            cause=CAUSE_STALE_EPOCH)
                cause = CAUSE_STALE_EPOCH
                continue
            buckets = [per_bucket[offs[0]], per_bucket[offs[1]]]
            cands = self._locate(key, buckets, offs)
            slot_off, slot_val, obj, stale = \
                yield from self._verify_candidates(key, cands, cause=cause)
            if obj is None:
                if stale and attempts <= MAX_OP_RETRIES:
                    cause = CAUSE_FP_COLLISION
                    continue             # mid-write / bounced object read
                return OpResult(NOT_FOUND, rtts=3)
            return OpResult(OK, value=obj["value"], rtts=3)

    # ----------------------------------------------------------- write ops
    def _prepare_object(self, key: int, value, opcode: int):
        """Allocate + build the object (log entry embedded). No verbs yet."""
        vlen = len(value)
        sc = L.size_class_for(L.obj_words_needed(vlen))
        st = yield from self._ensure_free(sc)
        if st == FULL:
            return None
        ptr, next_ptr, prev_ptr = self._take_obj(sc)
        words, sc2 = L.build_object(key, value, next_ptr, prev_ptr, opcode)
        assert sc2 == sc  # lint: allow-assert (hot path; both derive from vlen)
        self._pending_mid = words[len(words) - 2]
        return ptr, sc, prev_ptr, words

    def op_insert(self, key: int, value):
        prep = yield from self._prepare_object(key, value, L.OPCODE_INSERT)
        if prep is None:
            return OpResult(FULL)
        ptr, sc, prev_ptr, words = prep
        fp = L.fingerprint(key)
        region = self._index_region(key)
        v_new = int(L.pack_slot(fp, sc, ptr))
        retries = 0
        cause = CAUSE_NONE
        while True:
            # Phase 1: write KV (all replicas) + read both index buckets
            out = yield from self._read_index_for(
                key, self._write_obj_verbs(ptr, words), cause=cause)
            buckets, base_offs, wres = out
            if buckets is None or any(w is None for w in wres):
                # index read or an object-replica write bounced: a dead MN
                # (crash-stop) or a stale lease epoch (membership change /
                # migration cutover committed mid-phase).  Acking with a
                # replica hole would lose the write on the next re-homing
                # — report, wait for the membership commit, start over.
                yield MasterCall("fail_report", payload=dict(cid=self.cid))
                yield Phase([], label="wait_membership",
                            cause=CAUSE_STALE_EPOCH)
                cause = CAUSE_STALE_EPOCH
                continue
            # duplicate key?  -> treat as racing UPDATE on the existing slot
            cands = self._locate(key, buckets, base_offs)
            target = None
            v_old = 0
            if cands:
                slot_off2, slot_val2, obj2, stale = \
                    yield from self._verify_candidates(key, cands, cause=cause)
                if obj2 is not None:
                    target, v_old = slot_off2, slot_val2
                elif stale:
                    retries += 1
                    if retries > MAX_OP_RETRIES:
                        return OpResult(FULL)
                    cause = CAUSE_FP_COLLISION
                    continue
            if target is None:
                empty = None
                for wordsb, base in zip(buckets, base_offs):
                    empty = race.find_empty(wordsb, base)
                    if empty is not None:
                        break
                if empty is None:
                    return OpResult(FULL)
                target, v_old = empty, 0
            status, rule, fin = yield from self._snapshot_write(
                region, target, v_old, v_new, ptr, sc, prev_ptr, cause=cause)
            if status == "RETRY":
                retries += 1
                if retries > MAX_OP_RETRIES:
                    return OpResult(FULL)
                cause = CAUSE_CAS_LOST
                continue
            if status != OK:
                return OpResult(status, rule=rule)
            if v_old == 0 and rule in (LOSE, FINISH, "MASTER_LOSE") \
                    and not UNSAFE_ACK_LOST_EMPTY_CAS:
                # Lost an *empty-slot* race: the winner may have inserted a
                # DIFFERENT key there, so returning OK would acknowledge a
                # write that is nowhere in the index.  Retry from the top
                # (RACE insert retry): the index re-read either finds our
                # key (a same-key racer won -> upsert that slot) or a fresh
                # empty slot; the object words are rewritten first, since
                # the loser path reset our used bit.
                retries += 1
                if retries > MAX_OP_RETRIES:
                    return OpResult(FULL)
                cause = CAUSE_CAS_LOST
                continue
            bg = []
            if rule in (R1, R2, R3, "MASTER_WIN", "CR") and v_old != 0 \
                    and (L.slot_ptr(v_old) != ptr or UNSAFE_FREE_OWN_ON_RETRY):
                # v_old pointing at our OWN object means an epoch-bounced
                # retry re-observed its half-installed value (the cutover
                # repair adopts backups): there is no old object to free —
                # freeing would unlink the object the slot now references.
                bg += self._free_obj_verbs(v_old)          # free overwritten obj
                bg += self._mark_invalid_verbs(v_old)      # cache invalidation
            if bg:
                yield from self._bg_cleanup(bg, "bg:free_old")
            if self.enable_cache:
                self.cache[key] = CacheEntry(target, v_new, access=1,
                                             region=region,
                                             shard_ver=self._shard_ver(region))
            if self.pool.ordered_regions:
                # ordered keydir maintenance BEFORE the ack: a committed
                # key must be scan-visible (core/ordered.py contract)
                if (yield from ordered.ord_ensure(self, key)) == FULL:
                    self.ord_full_drops += 1
            return OpResult(OK, rule=rule)

    def op_update(self, key: int, value):
        prep = yield from self._prepare_object(key, value, L.OPCODE_UPDATE)
        if prep is None:
            return OpResult(FULL)
        ptr, sc, prev_ptr, words = prep
        fp = L.fingerprint(key)
        region = self._index_region(key)
        v_new = int(L.pack_slot(fp, sc, ptr))
        retries = 0
        ce = self.cache.get(key) if self.enable_cache else None
        use_cache = (ce is not None
                     and ce.invalid_ratio <= self.cache_threshold
                     and self._cache_fresh(ce, region))
        if ce is not None:
            ce.access += 1
        obs = self.pool._obs
        if obs is not None:
            obs.heat_key64(key)      # buffered; hashed vectorized at flush
        cause = CAUSE_NONE
        while True:
            target = v_old = None
            if use_cache and retries == 0:
                sv = ce.slot_val
                verbs = (self._write_obj_verbs(ptr, words)
                         + [Verb("read", region=region, replica=0,
                                 off=ce.slot_off, n=1),
                            self._read_obj_verb(L.slot_ptr(sv), L.slot_size_class(sv))])
                res = yield Phase(verbs, label="1:write+cached_read",
                                  cause=cause)
                nrep = self._obj_region_replicas(L.ptr_region(ptr))
                if any(w is None for w in res[:nrep]):
                    # an object-replica write bounced (dead MN / stale
                    # epoch): never ack with a replica hole — see op_insert
                    yield MasterCall("fail_report", payload=dict(cid=self.cid))
                    yield Phase([], label="wait_membership",
                                cause=CAUSE_STALE_EPOCH)
                    cause = CAUSE_STALE_EPOCH
                    continue
                slot_raw, kv_raw = res[nrep], res[nrep + 1]
                if slot_raw is not None and kv_raw is not None:
                    cur = int(slot_raw[0])
                    obj = L.parse_object(list(kv_raw))
                    if cur == int(sv) and obj["key"] == key and obj["used"] and obj["crc_ok"]:
                        target, v_old = ce.slot_off, cur
                    else:
                        ce.invalid += 1
                        if (cur != 0 and L.slot_fp(cur) == fp):
                            # slot changed but fp still ours: verify new object
                            r2 = yield Phase([self._read_obj_verb(
                                L.slot_ptr(cur), L.slot_size_class(cur))],
                                label="2:read_kv")
                            if r2[0] is not None:
                                o2 = L.parse_object(list(r2[0]))
                                if o2["key"] == key and o2["used"] and o2["crc_ok"]:
                                    target, v_old = ce.slot_off, cur
                elif slot_raw is None:
                    yield MasterCall("fail_report", payload=dict(cid=self.cid))
                    yield Phase([], label="wait_membership",
                                cause=CAUSE_STALE_EPOCH)
                    cause = CAUSE_STALE_EPOCH
                    continue
            if target is None:
                extra = self._write_obj_verbs(ptr, words) if (not use_cache or retries > 0) else []
                out = yield from self._read_index_for(key, extra, cause=cause)
                buckets, base_offs, wres = out
                if buckets is None or any(w is None for w in wres):
                    yield MasterCall("fail_report", payload=dict(cid=self.cid))
                    yield Phase([], label="wait_membership",
                                cause=CAUSE_STALE_EPOCH)
                    cause = CAUSE_STALE_EPOCH
                    continue
                cands = self._locate(key, buckets, base_offs)
                slot_off2, slot_val2, obj2, stale = \
                    yield from self._verify_candidates(key, cands, cause=cause)
                if obj2 is None:
                    if stale:
                        retries += 1
                        use_cache = False
                        if retries > MAX_OP_RETRIES:
                            return OpResult(FULL)
                        cause = CAUSE_FP_COLLISION
                        continue
                    yield from self._bg_cleanup(
                        self._reset_used_verbs(ptr, sc, prev_ptr),
                        "abort_reset")
                    return OpResult(NOT_FOUND)
                target, v_old = slot_off2, slot_val2
            status, rule, fin = yield from self._snapshot_write(
                region, target, v_old, v_new, ptr, sc, prev_ptr, cause=cause)
            if status == "RETRY":
                retries += 1
                use_cache = False
                if retries > MAX_OP_RETRIES:
                    return OpResult(FULL)
                cause = CAUSE_CAS_LOST
                continue
            if status != OK:
                return OpResult(status, rule=rule)
            bg = []
            if rule in (R1, R2, R3, "MASTER_WIN", "CR") \
                    and (L.slot_ptr(v_old) != ptr or UNSAFE_FREE_OWN_ON_RETRY):
                # same own-object guard as op_insert: an epoch-bounced retry
                # can re-observe its own half-installed value as v_old
                bg += self._free_obj_verbs(v_old)
                bg += self._mark_invalid_verbs(v_old)
            if bg:
                yield from self._bg_cleanup(bg, "bg:free_old")
            if self.enable_cache:
                e = self.cache.setdefault(key, CacheEntry(target, v_new))
                e.slot_off, e.slot_val = target, v_new
                e.region, e.shard_ver = region, self._shard_ver(region)
            return OpResult(OK, rule=rule)

    def op_delete(self, key: int):
        # §4.5: DELETE allocates a temporary object recording the log entry +
        # target key, reclaimed when the request finishes.
        prep = yield from self._prepare_object(key, [], L.OPCODE_DELETE)
        if prep is None:
            return OpResult(FULL)
        ptr, sc, prev_ptr, words = prep
        region = self._index_region(key)
        retries = 0
        cause = CAUSE_NONE
        while True:
            out = yield from self._read_index_for(
                key, self._write_obj_verbs(ptr, words), cause=cause)
            buckets, base_offs, wres = out
            if buckets is None or any(w is None for w in wres):
                yield MasterCall("fail_report", payload=dict(cid=self.cid))
                yield Phase([], label="wait_membership",
                            cause=CAUSE_STALE_EPOCH)
                cause = CAUSE_STALE_EPOCH
                continue
            cands = self._locate(key, buckets, base_offs)
            slot_off2, slot_val2, obj2, stale = \
                yield from self._verify_candidates(key, cands, cause=cause)
            if obj2 is None:
                if stale:
                    retries += 1
                    if retries > MAX_OP_RETRIES:
                        return OpResult(FULL)
                    cause = CAUSE_FP_COLLISION
                    continue
                yield from self._bg_cleanup(
                    self._reset_used_verbs(ptr, sc, prev_ptr),
                    "abort_reset")
                return OpResult(NOT_FOUND)
            status, rule, fin = yield from self._snapshot_write(
                region, slot_off2, slot_val2, 0, ptr, sc, prev_ptr,
                cause=cause)
            if status == "RETRY":
                retries += 1
                if retries > MAX_OP_RETRIES:
                    return OpResult(FULL)
                cause = CAUSE_CAS_LOST
                continue
            if status != OK:
                return OpResult(status, rule=rule)
            bg = []
            if rule in (R1, R2, R3, "MASTER_WIN", "CR"):
                bg += self._free_obj_verbs(slot_val2)
                bg += self._mark_invalid_verbs(slot_val2)
            # reclaim the temp DELETE object (free + reset used)
            own_slotval = int(L.pack_slot(L.fingerprint(key), sc, ptr))
            bg += self._free_obj_verbs(own_slotval)
            bg += self._reset_used_verbs(ptr, sc, prev_ptr)
            yield from self._bg_cleanup(bg, "bg:del_cleanup")
            self.cache.pop(key, None)
            if self.pool.ordered_regions:
                # clear the keydir entry (re-checks RACE: a racing
                # re-insert that committed gets its entry re-ensured)
                yield from ordered.ord_clear(self, key)
            return OpResult(OK, rule=rule)

    # --------------------------------------------------- owner-side reclaim
    def op_reclaim(self):
        """Background task (§4.4): scan free bitmaps of owned blocks, reclaim
        freed objects into local FIFO free lists, reset their used bits."""
        reclaimed = 0
        for sc, st in list(self.slab.items()):
            scw = L.size_class_words(sc)
            for (region, blk) in st.blocks:
                bmoff = self.pool.bitmap_base(blk)
                res = yield Phase([Verb("read", region=region, replica=0,
                                        off=bmoff, n=self.cfg.bitmap_words)],
                                  label="bg:read_bitmap", background=True)
                if res[0] is None:
                    continue
                bm = list(res[0])
                base = self.pool.block_base(blk)
                clear_verbs = []
                for w_i, w in enumerate(bm):
                    w = int(w)
                    while w:
                        bit = (w & -w).bit_length() - 1
                        w &= w - 1
                        obj_idx = w_i * 64 + bit
                        off = base + (obj_idx * L.MIN_OBJ_WORDS)
                        if (off - base) % scw != 0:
                            continue  # bit granularity finer than this class
                        ptr = self._ptr_of(region, off)
                        st.free.append(ptr)
                        reclaimed += 1
                        delta = 1 << (obj_idx % 64)
                        for i in range(self._obj_region_replicas(region)):
                            clear_verbs.append(Verb("faa", region=region,
                                                    replica=i, off=bmoff + w_i,
                                                    delta=-delta))
                        tail = int(L.pack_log_tail(0, used=False))
                        for i in range(self._obj_region_replicas(region)):
                            clear_verbs.append(Verb("write", region=region,
                                                    replica=i,
                                                    off=off + scw - 1,
                                                    words=[tail]))
                if clear_verbs:
                    yield Phase(clear_verbs, label="bg:reclaim", background=True)
        return OpResult(OK, value=[reclaimed])

    # ----------------------------------------------------- ordered scans
    def op_scan(self, start: int, count: int, *, hint: int = -1,
                batched: bool = True):
        """SCAN(start_key, count) over the ordered keydir (core/ordered.py):
        the next ``count`` live keys >= start in key order, values fetched
        and validated through the RACE index in batched phases."""
        return ordered.op_scan(self, start, count, hint=hint,
                               batched=batched)

    def op_range(self, start: int, end: int, *, hint: int = -1,
                 batched: bool = True):
        """RANGE(start, end): every live key in [start, end) with its
        value, in key order."""
        return ordered.op_range(self, start, end, hint=hint,
                                batched=batched)
