"""The first-class failure surface of the FUSEE cluster (§5, Alg. 3-4).

FUSEE's distinguishing claim is that *clients* handle metadata corruption
and membership changes under failures; this module makes that machinery a
public API instead of a test backdoor:

* typed errors — ``ClientCrashed`` (submits on a crashed/removed client)
  and ``SchedulerStalled`` (the backend has unresolved ops but the
  scheduler has no runnable work), replacing bare asserts/RuntimeErrors;
* ``CRASHED`` op outcome — in-flight futures of a crashed client resolve
  to a typed *retriable* ``OpResult`` instead of hanging (events.py);
* ``FaultPlan`` / ``FaultInjector`` — declarative fault schedules
  (crash_client / crash_mn / recover_client at tick- or completed-op-count
  boundaries) that drive the scheduler via its tick hooks, replacing the
  ad-hoc crash calls previously scattered across tests and benchmarks;
* ``ClusterHealth`` — the observability snapshot behind
  ``FuseeCluster.health()``: per-MN liveness, lease epoch, per-client
  pipeline depth / cache state, and cumulative ``RecoveryStats``.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import TYPE_CHECKING, Dict, Iterator, List, Optional, Tuple

from .master import RecoveryStats

if TYPE_CHECKING:                      # pragma: no cover - typing only
    from .sim import Scheduler
    from .store import FuseeCluster


# ------------------------------------------------------------- typed errors
class ClusterError(RuntimeError):
    """Base of every typed failure raised by the cluster surface."""


class ClientCrashed(ClusterError):
    """Submit (or store binding) rejected: the client is crashed, removed,
    or unknown.  Retriable on any live client — the op never entered the
    pipeline."""

    def __init__(self, cid: int, reason: str = "crashed"):
        self.cid = cid
        self.reason = reason
        super().__init__(
            f"client {cid} is {reason}; the op was not submitted "
            f"(resubmit on a live client or add_client() a replacement)")


class SchedulerStalled(ClusterError):
    """The backend holds unresolved ops but the scheduler has no runnable
    work — a wiring bug (e.g. a future detached from its record), never a
    legal protocol state."""


class ProtocolViolation(ClusterError):
    """An internal protocol invariant was broken — a bug in this repo (or
    a test harness misusing an internal surface), never a legal runtime
    state.  The message carries reproducing context (cid / op / region /
    tick) so a failing storm seed can be replayed; the protocol lint
    (repro.analysis.lint, rule L005) requires protocol code to raise this
    instead of bare ``assert``."""


class RegionLost(ClusterError):
    """A region has no live replica left: more than r-1 MNs hosting it
    failed simultaneously, which is outside the paper's §5.1 fault model
    (data loss — recovery cannot proceed)."""

    def __init__(self, region: int, detail: str = ""):
        self.region = region
        super().__init__(
            f"region {region} lost: no live replica remains "
            f"(>= r simultaneous MN failures){' — ' + detail if detail else ''}")


class InsufficientReplicas(ClusterError):
    """``remove_mn`` rejected: draining the node would leave fewer ring
    members than the replication factor, so some region could not keep r
    replicas.  The membership is unchanged — add an MN first."""


class OrderedIndexDisabled(ClusterError):
    """SCAN/RANGE rejected: the cluster was built without the ordered
    keydir (``DMConfig.ordered_index=False``).  Range queries need the
    ordered secondary index (core/ordered.py) — enable it at
    construction; the hash index alone cannot answer them."""

    def __init__(self):
        super().__init__(
            "scan/range require DMConfig(ordered_index=True): the RACE "
            "hash index cannot answer range queries")


# ------------------------------------------------------------- fault plans
_ACTIONS = ("crash_client", "crash_mn", "recover_client",
            "add_mn", "remove_mn")


@dataclass(frozen=True)
class FaultEvent:
    """One scheduled fault: ``action`` on ``target`` when the trigger
    boundary passes.  Exactly one of ``at_tick`` (scheduler tick) or
    ``after_ops`` (cluster-wide completed-op count) must be set."""
    action: str
    target: int
    at_tick: Optional[int] = None
    after_ops: Optional[int] = None
    reassign_to: Optional[int] = None   # recover_client only

    def __post_init__(self):
        if self.action not in _ACTIONS:
            raise ValueError(f"unknown fault action {self.action!r}; "
                             f"expected one of {_ACTIONS}")
        if (self.at_tick is None) == (self.after_ops is None):
            raise ValueError("exactly one of at_tick / after_ops required")

    def due(self, sched: "Scheduler") -> bool:
        if self.at_tick is not None:
            return sched.tick >= self.at_tick
        return sched.completed_ops >= self.after_ops


class FaultPlan:
    """Declarative fault schedule; build with the chainable helpers:

        plan = (FaultPlan()
                .crash_mn(2, after_ops=48)
                .crash_client(0, after_ops=56)
                .recover_client(0, reassign_to=1, after_ops=60))
        injector = cluster.inject(plan)

    Events with the same trigger fire in plan order."""

    def __init__(self, events: Optional[List[FaultEvent]] = None):
        self.events: List[FaultEvent] = list(events or [])

    def _add(self, ev: FaultEvent) -> "FaultPlan":
        self.events.append(ev)
        return self

    def crash_client(self, cid: int, *, at_tick: Optional[int] = None,
                     after_ops: Optional[int] = None) -> "FaultPlan":
        return self._add(FaultEvent("crash_client", cid, at_tick=at_tick,
                                    after_ops=after_ops))

    def crash_mn(self, mid: int, *, at_tick: Optional[int] = None,
                 after_ops: Optional[int] = None) -> "FaultPlan":
        return self._add(FaultEvent("crash_mn", mid, at_tick=at_tick,
                                    after_ops=after_ops))

    def recover_client(self, cid: int, *, reassign_to: Optional[int] = None,
                       at_tick: Optional[int] = None,
                       after_ops: Optional[int] = None) -> "FaultPlan":
        return self._add(FaultEvent("recover_client", cid, at_tick=at_tick,
                                    after_ops=after_ops,
                                    reassign_to=reassign_to))

    def add_mn(self, *, at_tick: Optional[int] = None,
               after_ops: Optional[int] = None) -> "FaultPlan":
        """Membership event: join a fresh MN mid-run; shard migrations
        ride the workload's scheduler ticks (core/migrate.py)."""
        return self._add(FaultEvent("add_mn", -1, at_tick=at_tick,
                                    after_ops=after_ops))

    def remove_mn(self, mid: int, *, at_tick: Optional[int] = None,
                  after_ops: Optional[int] = None) -> "FaultPlan":
        """Membership event: gracefully drain + retire an MN mid-run."""
        return self._add(FaultEvent("remove_mn", mid, at_tick=at_tick,
                                    after_ops=after_ops))

    @staticmethod
    def storm(rng, *, clients, mns: int, replication: int = 2,
              n_client_crashes: int = 2, n_mn_crashes: int = 1,
              first_op: int = 8, spacing: int = 10,
              recover_delay: int = 8, n_add_mns: int = 0,
              remove_added: bool = False,
              crash_during_migration: bool = False) -> "FaultPlan":
        """A randomized fault storm, fully determined by ``rng`` (pass a
        ``SimRng`` substream — ``cluster.rng.stream('faults')`` — so the
        storm replays bit-identically from the run seed).

        Crashes ``n_client_crashes`` distinct clients at spaced
        completed-op boundaries, each recovered ``recover_delay`` ops
        later with its log reassigned to a never-crashed survivor; crashes
        up to ``n_mn_crashes`` MNs, capped at ``mns - replication`` so no
        region ever loses all its replicas.  Safety of the caps — not the
        timing — is what makes "no acknowledged write is lost" a fair
        invariant to assert after the storm.

        Membership churn: ``n_add_mns`` joins fresh MNs mid-storm (shard
        migrations ride the workload ticks); ``remove_added`` drains each
        added MN again one spacing later (a full scale-out/scale-in
        cycle across live cutovers); ``crash_during_migration`` crashes
        one extra original MN two ops after the first join — i.e. while
        shard copies are in flight — capped so no region can lose all
        replicas (the post-join member count covers the extra crash)."""
        clients = list(clients)
        n_cc = min(n_client_crashes, max(len(clients) - 1, 0))
        victims = [clients[int(i)] for i in
                   rng.choice(len(clients), size=n_cc, replace=False)]
        survivors = [c for c in clients if c not in victims]
        n_mc = max(0, min(n_mn_crashes, mns - replication))
        mn_victims = [int(m) for m in
                      rng.choice(mns, size=n_mc, replace=False)]
        timeline: List[Tuple[str, int]] = \
            [("client", c) for c in victims] + [("mn", m) for m in mn_victims]
        order = rng.permutation(len(timeline))
        plan = FaultPlan()
        t = first_op
        for i in order:
            kind, target = timeline[int(i)]
            if kind == "client":
                heir = survivors[int(rng.integers(len(survivors)))] \
                    if survivors else None
                plan.crash_client(target, after_ops=t)
                plan.recover_client(target, reassign_to=heir,
                                    after_ops=t + recover_delay)
            else:
                plan.crash_mn(target, after_ops=t)
            t += spacing
        # membership churn rides after the base storm (draws only happen
        # when requested, so churn-free storms keep their seed sequences)
        crashed = set(mn_victims)
        n_removals = n_add_mns if remove_added else 0
        for i in range(n_add_mns):
            plan.add_mn(after_ops=t)
            if crash_during_migration and i == 0:
                cand = [m for m in range(mns) if m not in crashed]
                # one extra crash is safe iff the ring keeps >= replication
                # members after ALL planned churn (adds, this crash, and
                # any later removals of the added MNs)
                if cand and (mns + n_add_mns - len(crashed) - 1
                             - n_removals) >= replication:
                    vm = cand[int(rng.integers(len(cand)))]
                    crashed.add(vm)
                    plan.crash_mn(vm, after_ops=t + 2)
            t += spacing
            if remove_added:
                plan.remove_mn(mns + i, after_ops=t)
                t += spacing
        return plan

    def __iter__(self) -> Iterator[FaultEvent]:
        return iter(self.events)

    def __len__(self) -> int:
        return len(self.events)


class FaultInjector:
    """Binds a ``FaultPlan`` to a cluster: installed as a scheduler tick
    hook, it fires each event (through the public cluster surface, so
    recovery stats accumulate) the first time its boundary passes."""

    def __init__(self, cluster: "FuseeCluster", plan: FaultPlan):
        self.cluster = cluster
        self.pending: List[FaultEvent] = list(plan)
        self.fired: List[Tuple[int, FaultEvent]] = []

    @property
    def done(self) -> bool:
        return not self.pending

    def poll(self, sched: "Scheduler"):
        while True:
            due = next((e for e in self.pending if e.due(sched)), None)
            if due is None:
                if not self.pending:   # plan exhausted: stop polling forever
                    sched.remove_tick_hook(self.poll)
                return
            self.pending.remove(due)
            self._fire(due, sched)

    def _fire(self, ev: FaultEvent, sched: "Scheduler"):
        if ev.action == "crash_client":
            self.cluster.crash_client(ev.target)
        elif ev.action == "crash_mn":
            self.cluster.crash_mn(ev.target)
        elif ev.action == "add_mn":
            self.cluster.add_mn(wait=False)
        elif ev.action == "remove_mn":
            self.cluster.remove_mn(ev.target, wait=False)
        else:
            self.cluster.recover_client(ev.target,
                                        reassign_to_cid=ev.reassign_to)
        self.fired.append((sched.tick, ev))
        obs = sched.obs
        if obs is not None:
            # auto-dump the flight ring once per injected fault class
            # (no-op unless the hub was armed with a dump_dir)
            obs.dump("fault_" + ev.action)


# ------------------------------------------------------------ health views
@dataclass
class MNHealth:
    mid: int
    alive: bool
    primary_regions: int
    hosted_regions: int
    bytes_served: int
    retired: bool = False       # gracefully removed (remove_mn), not crashed


@dataclass
class ClientHealth:
    cid: int
    status: str                 # 'live' | 'crashed' | 'removed'
    epoch: int
    inflight: int               # current pipeline depth
    cache_entries: int
    completed_ops: int
    crashed_ops: int            # ops of this client resolved CRASHED


@dataclass
class ClusterHealth:
    """Snapshot returned by ``FuseeCluster.health()``."""
    epoch: int
    tick: int
    mns: List[MNHealth] = field(default_factory=list)
    clients: List[ClientHealth] = field(default_factory=list)
    recovery: RecoveryStats = field(default_factory=RecoveryStats)
    client_recoveries: int = 0
    mn_recoveries: int = 0
    crashed_ops: int = 0
    migrating_regions: int = 0      # regions inside a live-migration window
    migrations: List[Dict] = field(default_factory=list)  # per-region detail

    @property
    def alive_mns(self) -> int:
        return sum(m.alive for m in self.mns)

    @property
    def retired_mns(self) -> int:
        return sum(m.retired for m in self.mns)

    @property
    def live_clients(self) -> int:
        return sum(c.status == "live" for c in self.clients)

    def summary(self) -> str:
        return (f"epoch={self.epoch} tick={self.tick} "
                f"mns={self.alive_mns}/{len(self.mns)} alive "
                f"clients={self.live_clients}/{len(self.clients)} live "
                f"recoveries={self.client_recoveries}+{self.mn_recoveries}mn "
                f"crashed_ops={self.crashed_ops}")


def accumulate_recovery(total: RecoveryStats, st: RecoveryStats):
    """Fold one recovery's stats into a cumulative total (health view)."""
    for f in dataclasses.fields(RecoveryStats):
        setattr(total, f.name, getattr(total, f.name) + getattr(st, f.name))
