"""Linearizability checking for per-key KV histories.

FUSEE's correctness claim (§A.3) is that each replicated index slot behaves
as a linearizable register with last-writer-wins semantics, which lifts to
per-key linearizability of SEARCH/INSERT/UPDATE/DELETE (out-of-place values
are unique).  This module implements a Wing&Gong-style DFS checker over the
real-time partial order: feasible for the small histories the property tests
generate (<= ~10 concurrent ops per key).

Semantics of the sequential specification (a single register per key):
  insert(v): value <- v            (our INSERT upserts on duplicates)
  update(v): value <- v if present else NOT_FOUND (no state change)
  delete():  OK        -> value <- ABSENT  (a *blind write* of ABSENT: the
                          paper's uniqueness argument does not apply to the
                          all-writers-write-NULL case, so concurrent deleters
                          may all report OK; see DESIGN.md §deviations)
             NOT_FOUND -> requires value already ABSENT (observed absence)
  search():  returns current value or ABSENT
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import lru_cache
from typing import FrozenSet, List, Optional, Tuple

ABSENT = None


@dataclass(frozen=True)
class HOp:
    op_id: int
    kind: str                    # insert | update | delete | search
    inv: int
    resp: int
    wrote: Optional[tuple]       # value written (insert/update), else None
    read: Optional[tuple]        # value returned (search), ABSENT -> ('<absent>',)
    status: str = "OK"           # OK | NOT_FOUND


def check_linearizable(ops: List[HOp], initial=ABSENT) -> bool:
    """DFS over linearization prefixes with memoization."""
    n = len(ops)
    ops = sorted(ops, key=lambda o: o.op_id)
    idx = {o.op_id: i for i, o in enumerate(ops)}

    def transition(o: HOp, value):
        if o.kind == "search":
            if o.status == "NOT_FOUND":
                return (value is ABSENT), value
            return (value is not ABSENT and tuple(value) == tuple(o.read)), value
        if o.kind == "insert":
            return o.status == "OK", tuple(o.wrote)
        if o.kind == "update":
            if value is ABSENT:
                return o.status == "NOT_FOUND", value
            return o.status == "OK", tuple(o.wrote)
        if o.kind == "delete":
            if o.status == "NOT_FOUND":
                return value is ABSENT, value
            return True, ABSENT  # blind write of ABSENT
        raise ValueError(o.kind)

    seen = set()

    def dfs(remaining: FrozenSet[int], value) -> bool:
        if not remaining:
            return True
        key = (remaining, value)
        if key in seen:
            return False
        # candidate = ops with no other remaining op fully preceding them
        rem_ops = [ops[idx[i]] for i in remaining]
        min_resp = min(o.resp for o in rem_ops)
        for o in rem_ops:
            if o.inv > min_resp:
                continue  # some remaining op completed before this one began
            ok, nv = transition(o, value)
            if not ok:
                continue
            if dfs(remaining - {o.op_id}, nv):
                return True
        seen.add(key)
        return False

    return dfs(frozenset(o.op_id for o in ops), initial)


def records_to_hops(records, key) -> List[HOp]:
    """Convert sim.OpRecord list to per-key HOps.

    ``key`` may be an int (protocol key space) or bytes/str (public API
    key space) — the latter is encoded through core/codec.py, matching
    what the pipelined API stamped onto the records.  Fused multi-key
    SEARCH batches appear as one ``search_batch`` parent record (key None,
    skipped here) plus one expanded per-key ``search`` record each.
    """
    if not isinstance(key, int):
        from .codec import encode_key
        key = encode_key(key)
    out = []
    for r in records:
        if r.key != key or r.result is None:
            continue
        if r.kind not in ("insert", "update", "delete", "search"):
            continue  # scan/range/search_batch: not per-key register ops
        status = r.result.status
        if status not in ("OK", "NOT_FOUND"):
            continue  # FULL etc. — excluded from register semantics
        wrote = tuple(r.value) if r.kind in ("insert", "update") and r.value is not None else None
        read = tuple(r.result.value) if (r.kind == "search" and r.result.value is not None) else None
        out.append(HOp(op_id=r.op_id, kind=r.kind, inv=r.inv_tick,
                       resp=r.resp_tick, wrote=wrote, read=read, status=status))
    return out
