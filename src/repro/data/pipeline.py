"""Deterministic synthetic token pipeline.

Generates a reproducible Markov-ish token stream per (seed, step, shard) —
no filesystem dependency, identical across restarts, and cheap enough to
never bottleneck the step.  The stream has learnable structure (a planted
bigram table) so training loss decreases and the end-to-end example can show
real learning curves rather than noise.

The pipeline is *sharded at the source*: each data-parallel host generates
only its shard (``shard_id``/``num_shards``), the standard input-pipeline
pattern at pod scale; ``jax.make_array_from_process_local_data`` would
assemble the global array in a true multi-host run.  A background thread
prefetches ``prefetch`` batches ahead.
"""
from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from typing import Dict, Iterator, Optional

import numpy as np


@dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    structure: int = 64     # planted bigram classes (signal to learn)
    prefetch: int = 2


class SyntheticLM:
    """Deterministic synthetic LM dataset with a planted bigram structure."""

    def __init__(self, cfg: DataConfig, shard_id: int = 0,
                 num_shards: int = 1):
        assert cfg.global_batch % num_shards == 0
        self.cfg = cfg
        self.shard_id = shard_id
        self.num_shards = num_shards
        self.local_batch = cfg.global_batch // num_shards
        rng = np.random.default_rng(cfg.seed)
        # planted structure: each token class prefers a successor class
        self.succ = rng.permutation(cfg.structure)
        self._q: "queue.Queue" = queue.Queue(maxsize=cfg.prefetch)
        self._thread: Optional[threading.Thread] = None
        self._stop = threading.Event()

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        """Deterministic batch for a global step (restart-safe)."""
        cfg = self.cfg
        ss = np.random.SeedSequence(  # seeded from cfg+step: restart-safe
            [cfg.seed, step, self.shard_id, self.num_shards])
        rng = np.random.default_rng(ss)
        B, S, V, C = self.local_batch, cfg.seq_len, cfg.vocab, cfg.structure
        cls = np.empty((B, S), np.int64)
        cls[:, 0] = rng.integers(0, C, B)
        noise = rng.random((B, S)) < 0.15
        rnd = rng.integers(0, C, (B, S))
        for t in range(1, S):
            nxt = self.succ[cls[:, t - 1]]
            cls[:, t] = np.where(noise[:, t], rnd[:, t], nxt)
        offs = rng.integers(0, max(1, V // C), (B, S))
        tokens = (cls * (V // C) + offs).clip(0, V - 1).astype(np.int32)
        labels = np.concatenate([tokens[:, 1:], -np.ones((B, 1), np.int32)],
                                axis=1)
        return {"tokens": tokens, "labels": labels}

    # ------------------------------------------------------ prefetch loop --
    def start(self, first_step: int = 0):
        def worker():
            step = first_step
            while not self._stop.is_set():
                b = self.batch_at(step)
                while not self._stop.is_set():
                    try:
                        self._q.put((step, b), timeout=0.1)
                        break
                    except queue.Full:
                        continue
                step += 1

        self._thread = threading.Thread(target=worker, daemon=True)
        self._thread.start()

    def stop(self):
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=2)

    def __iter__(self) -> Iterator:
        while True:
            yield self._q.get()


def make_batch_specs(resolver, batch_shape):
    """PartitionSpecs for a {tokens, labels} batch."""
    return {k: resolver.spec(("batch", None), batch_shape)
            for k in ("tokens", "labels")}
