from .ops import race_lookup  # noqa: F401
from .ref import (bucket_pair, fingerprint, hash32,  # noqa: F401
                  race_lookup_ref)
