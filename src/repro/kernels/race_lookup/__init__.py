from .ops import (build_shadow, hash32_np, race_lookup,  # noqa: F401
                  race_lookup_batch, race_lookup_np)
from .ref import (bucket_pair, fingerprint, hash32,  # noqa: F401
                  race_lookup_ref)
