"""Pure-jnp oracle for the batched RACE index probe.

Shares the 32-bit hash/slot packing with the JAX serving pool
(serving/slots_jax.py): a slot is ``fp:8 | ptr:24`` in a uint32-as-int32
word; fp 0 is reserved for "empty"/mismatch.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

MASK24 = (1 << 24) - 1


def hash32(x, seed: int):
    """xorshift-multiply hash on int32 lanes (exactly mirrored in-kernel)."""
    import numpy as np
    x = x.astype(jnp.uint32) + np.uint32(0x9E3779B9 * (seed + 1) & 0xFFFFFFFF)
    x = (x ^ (x >> 16)) * np.uint32(0x85EBCA6B)
    x = (x ^ (x >> 13)) * np.uint32(0xC2B2AE35)
    return (x ^ (x >> 16)).astype(jnp.uint32)


def fingerprint(keys):
    fp = (hash32(keys, 7) >> 24).astype(jnp.int32)
    return jnp.where(fp == 0, 1, fp)


def bucket_pair(keys, n_buckets: int):
    b1 = (hash32(keys, 1) % n_buckets).astype(jnp.int32)
    b2 = (hash32(keys, 2) % n_buckets).astype(jnp.int32)
    b2 = jnp.where(b2 == b1, (b1 + 1) % n_buckets, b2)
    return b1, b2


def race_lookup_ref(keys, index):
    """keys: (N,) int32; index: (n_buckets, slots) int32 (fp:8|ptr:24).

    Returns (ptr, found): ptr (N,) int32 (0 if miss), found (N,) bool.
    First fp-matching slot wins, bucket-1 slots before bucket-2 slots.
    """
    nb, spb = index.shape
    b1, b2 = bucket_pair(keys, nb)
    fp = fingerprint(keys)
    rows = jnp.stack([index[b1], index[b2]], axis=1).reshape(keys.shape[0],
                                                             2 * spb)
    slot_fp = (rows >> 24) & 0xFF
    match = slot_fp == fp[:, None]
    any_match = jnp.any(match, axis=1)
    first = jnp.argmax(match, axis=1)
    picked = jnp.take_along_axis(rows, first[:, None], axis=1)[:, 0]
    ptr = jnp.where(any_match, picked & MASK24, 0)
    return ptr.astype(jnp.int32), any_match
