"""jit'd public wrapper for the RACE index-probe kernel."""
from __future__ import annotations

from functools import partial

import jax

from .kernel import race_lookup_fwd
from .ref import race_lookup_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@partial(jax.jit, static_argnames=("use_kernel", "block_keys"))
def race_lookup(keys, index, *, block_keys: int = 256, use_kernel: bool = True):
    """Batched RACE probe: keys (N,) int32, index (n_buckets, spb) int32
    -> (ptr (N,) int32, found (N,) bool)."""
    if not use_kernel:
        return race_lookup_ref(keys, index)
    return race_lookup_fwd(keys, index, block_keys=block_keys,
                           interpret=not _on_tpu())
