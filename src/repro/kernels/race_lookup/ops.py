"""jit'd public wrapper for the RACE index-probe kernel, plus the
host-facing **batched entry point** used by the simulator's fleet mode.

``race_lookup`` is the jitted device API (jnp in / jnp out).
``race_lookup_batch`` is the fleet entry point: uint32 numpy in / numpy
out, pads the key batch to the kernel block size, and — because one fleet
tick probes on behalf of *every* client at once with constantly growing
shadow tables — routes through the Pallas kernel only where that is a
win (TPU); elsewhere it runs the exact numpy mirror of the kernel's
hash/probe sequence (one vectorized gather, no per-key work, no
per-shape recompiles).
"""
from __future__ import annotations

from functools import partial

import jax
import numpy as np

from repro.core.shadow import (MASK24, build_shadow,  # noqa: F401
                               hash32_np, race_lookup_np)

from .kernel import race_lookup_fwd
from .ref import race_lookup_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@partial(jax.jit, static_argnames=("use_kernel", "block_keys"))
def race_lookup(keys, index, *, block_keys: int = 256, use_kernel: bool = True):
    """Batched RACE probe: keys (N,) int32, index (n_buckets, spb) int32
    -> (ptr (N,) int32, found (N,) bool)."""
    if not use_kernel:
        return race_lookup_ref(keys, index)
    return race_lookup_fwd(keys, index, block_keys=block_keys,
                           interpret=not _on_tpu())


def race_lookup_batch(q: np.ndarray, table: np.ndarray, *,
                      block_keys: int = 256,
                      prefer_kernel: bool = None):
    """Fleet entry point: probe uint32 keys ``q`` (N,) against a uint32
    shadow table (nb, spb); returns (ptr (N,) uint32, found (N,) bool) as
    numpy arrays.  One invocation serves the whole batch — the caller
    (core/fleet.py, core/api.py) concatenates every client's keys for the
    tick before calling.

    ``prefer_kernel=None`` auto-selects: the Pallas kernel on TPU, the
    bit-identical numpy mirror elsewhere (interpret-mode Pallas would
    execute per-element and recompile per shape — exactly what a
    thousand-client tick cannot afford)."""
    q = np.ascontiguousarray(q, np.uint32)
    if prefer_kernel is None:
        prefer_kernel = _on_tpu()
    if prefer_kernel:
        try:
            import jax.numpy as jnp
            n = len(q)
            pad = -(-max(n, 1) // block_keys) * block_keys - n
            qp = jnp.asarray(np.concatenate(
                [q, np.zeros(pad, np.uint32)]).view(np.int32))
            ptr, found = race_lookup(qp, jnp.asarray(table.view(np.int32)),
                                     block_keys=block_keys)
            return (np.asarray(ptr[:n]).view(np.uint32).astype(np.uint32),
                    np.asarray(found[:n]))
        except Exception:       # pragma: no cover - jax-less fallback
            pass
    return race_lookup_np(q, table)
