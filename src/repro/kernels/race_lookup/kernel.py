"""Pallas TPU kernel for the batched RACE hash-index probe — the FUSEE
client SEARCH phase-1 (§4.2) as a serving hot-spot.

TPU adaptation of the one-sided-RDMA probe: the replicated index shard is
small metadata (n_buckets x slots_per_bucket x 4B; 4096x8 = 128KB) so the
whole shard is pinned in VMEM via its BlockSpec; keys stream in tiles.

The per-key bucket *gather* is the interesting part: TPU has no efficient
vector gather across sublanes, so the kernel uses the one-hot-matmul trick —
``one_hot(bucket_ids) @ index`` runs the gather on the MXU.  int32 slots
don't matmul, so the wrapper pre-splits the index into hi/lo 16-bit halves
held as f32 (exact: < 2^24), and the kernel recombines after the gather.

Grid: (N / BLOCK_KEYS,).  Hashing is int32 xorshift-multiply on the VPU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import MASK24


def _hash32(x, seed: int):
    import numpy as np
    x = x.astype(jnp.uint32) + np.uint32(0x9E3779B9 * (seed + 1) & 0xFFFFFFFF)
    x = (x ^ (x >> 16)) * np.uint32(0x85EBCA6B)
    x = (x ^ (x >> 13)) * np.uint32(0xC2B2AE35)
    return (x ^ (x >> 16)).astype(jnp.uint32)


def _lookup_kernel(keys_ref, hi_ref, lo_ref, ptr_ref, found_ref,
                   *, n_buckets, spb):
    keys = keys_ref[...]                              # (BK,)
    b1 = (_hash32(keys, 1) % n_buckets).astype(jnp.int32)
    b2 = (_hash32(keys, 2) % n_buckets).astype(jnp.int32)
    b2 = jnp.where(b2 == b1, (b1 + 1) % n_buckets, b2)
    fp = (_hash32(keys, 7) >> 24).astype(jnp.int32)
    fp = jnp.where(fp == 0, 1, fp)

    # MXU gather: one_hot(bucket) @ index_halves
    iota = jax.lax.broadcasted_iota(jnp.int32, (keys.shape[0], n_buckets), 1)
    oh1 = (iota == b1[:, None]).astype(jnp.float32)
    oh2 = (iota == b2[:, None]).astype(jnp.float32)
    hi = hi_ref[...]                                  # (n_buckets, spb) f32
    lo = lo_ref[...]
    r1 = jnp.concatenate([oh1 @ hi, oh1 @ lo], axis=1)   # (BK, 2*spb)
    r2 = jnp.concatenate([oh2 @ hi, oh2 @ lo], axis=1)
    rows_hi = jnp.concatenate([r1[:, :spb], r2[:, :spb]], axis=1)
    rows_lo = jnp.concatenate([r1[:, spb:], r2[:, spb:]], axis=1)
    rows = (rows_hi.astype(jnp.int32) * 65536
            + rows_lo.astype(jnp.int32))              # (BK, 2*spb)

    slot_fp = (rows >> 24) & 0xFF
    match = slot_fp == fp[:, None]
    any_match = jnp.any(match, axis=1)
    first = jnp.argmax(match, axis=1)
    picked = jnp.sum(jnp.where(
        jax.lax.broadcasted_iota(jnp.int32, match.shape, 1) == first[:, None],
        rows, 0), axis=1)
    ptr_ref[...] = jnp.where(any_match, picked & MASK24, 0).astype(jnp.int32)
    found_ref[...] = any_match


def race_lookup_fwd(keys, index, *, block_keys: int = 256,
                    interpret: bool = True):
    """keys: (N,) int32; index: (n_buckets, spb) int32 -> (ptr, found)."""
    N = keys.shape[0]
    nb, spb = index.shape
    block_keys = min(block_keys, N)
    assert N % block_keys == 0
    # pre-split into f32-exact 16-bit halves (the MXU gather operand)
    u = index.astype(jnp.uint32)
    hi = (u >> 16).astype(jnp.float32)
    lo = (u & 0xFFFF).astype(jnp.float32)

    kernel = functools.partial(_lookup_kernel, n_buckets=nb, spb=spb)
    return pl.pallas_call(
        kernel,
        grid=(N // block_keys,),
        in_specs=[
            pl.BlockSpec((block_keys,), lambda i: (i,)),
            pl.BlockSpec((nb, spb), lambda i: (0, 0)),   # resident in VMEM
            pl.BlockSpec((nb, spb), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_keys,), lambda i: (i,)),
            pl.BlockSpec((block_keys,), lambda i: (i,)),
        ],
        out_shape=[jax.ShapeDtypeStruct((N,), jnp.int32),
                   jax.ShapeDtypeStruct((N,), jnp.bool_)],
        interpret=interpret,
    )(keys, hi, lo)
