"""Pallas TPU kernels for the perf-critical compute layers, each with a
pure-jnp oracle (ref.py) and a jit'd wrapper (ops.py):

* flash_attention — train/prefill attention (online softmax, GQA-aware)
* paged_attention — decode attention over the FUSEE block pool
* race_lookup     — batched RACE hash-index probe (FUSEE SEARCH phase 1)
* leaf_probe      — batched ordered-index leaf search (SCAN locate phase)
* fleet_tick      — fused-tick READ sweep (paged slab gather via scalar
                    prefetch; the numpy exec_fused_tick stays the CPU
                    authority)

On CPU the kernels execute via ``interpret=True``; on TPU they compile to
Mosaic.  Correctness is swept over shapes/dtypes in tests/test_kernels.py.
"""
from .flash_attention import flash_attention, flash_attention_ref  # noqa
from .paged_attention import paged_attention, paged_attention_ref  # noqa
from .race_lookup import race_lookup, race_lookup_batch, race_lookup_ref  # noqa
from .leaf_probe import leaf_probe, leaf_probe_batch, leaf_probe_ref  # noqa
from .fleet_tick import fleet_read, fleet_read_sweep, fleet_read_ref  # noqa
