"""Pallas TPU paged decode-attention kernel.

One query token per sequence attends over the FUSEE block pool
``(n_blocks, t_blk, B, KV, hd)`` — the same page-major layout the
disaggregated KV store serves (pages = FUSEE objects; the leading axis is
what shards over "memory nodes").

Grid: (B * H, n_blocks).  The page axis is the *minor* grid dim, so the
online-softmax state (m, l, acc) lives in VMEM scratch across page visits
and the output is committed once on the last page — a single-pass
flash-decode.  Page tiles (t_blk, hd) stream HBM->VMEM at MXU-aligned
shapes; masking uses absolute positions derived from the page index, so
partially-filled tail pages are handled without branching.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(vl_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
                   *, t_blk, n_blocks, scale):
    pg = pl.program_id(1)

    @pl.when(pg == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[...].astype(jnp.float32) * scale       # (1, hd)
    k = k_ref[...].astype(jnp.float32)               # (t_blk, hd)
    v = v_ref[...].astype(jnp.float32)
    s = (k @ q.T)[:, 0]                              # (t_blk,)
    pos = pg * t_blk + jax.lax.iota(jnp.int32, t_blk)
    s = jnp.where(pos < vl_ref[0], s, NEG_INF)

    m_prev = m_ref[0]
    m_new = jnp.maximum(m_prev, jnp.max(s))
    p = jnp.exp(s - m_new)                           # (t_blk,)
    alpha = jnp.exp(m_prev - m_new)
    l_ref[0] = l_ref[0] * alpha + jnp.sum(p)
    acc_ref[...] = acc_ref[...] * alpha + (p[None, :] @ v)
    m_ref[0] = m_new

    @pl.when(pg == n_blocks - 1)
    def _commit():
        o_ref[...] = (acc_ref[...] / jnp.maximum(l_ref[0], 1e-30)
                      ).astype(o_ref.dtype)


def paged_attention_fwd(q, kc, vc, valid_len, *, interpret: bool = True):
    """q: (B, H, hd); kc/vc: (nb, tb, B, KV, hd) -> (B, H, hd)."""
    nb, tb, B, KV, hd = kc.shape
    H = q.shape[1]
    G = H // KV
    scale = hd ** -0.5
    vl = jnp.reshape(valid_len.astype(jnp.int32), (1,))
    grid = (B * H, nb)

    kernel = functools.partial(_decode_kernel, t_blk=tb, n_blocks=nb,
                               scale=scale)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),          # valid_len
            pl.BlockSpec((None, 1, hd), lambda bh, pg: (bh // H, bh % H, 0)),
            pl.BlockSpec((None, tb, None, None, hd),
                         lambda bh, pg: (pg, 0, bh // H, (bh % H) // G, 0)),
            pl.BlockSpec((None, tb, None, None, hd),
                         lambda bh, pg: (pg, 0, bh // H, (bh % H) // G, 0)),
        ],
        out_specs=pl.BlockSpec((None, 1, hd),
                               lambda bh, pg: (bh // H, bh % H, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((1,), jnp.float32),       # m
            pltpu.VMEM((1,), jnp.float32),       # l
            pltpu.VMEM((1, hd), jnp.float32),    # acc
        ],
        interpret=interpret,
    )(vl, q, kc, vc)
