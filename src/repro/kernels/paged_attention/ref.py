"""Pure-jnp oracle for paged decode attention over the FUSEE block pool."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def paged_attention_ref(q, kc, vc, valid_len):
    """q: (B, H, hd); kc/vc: (nb, tb, B, KV, hd); valid_len: scalar int.

    Attention of one query token per sequence over the block-paged cache,
    masked to the first ``valid_len`` positions.  Returns (B, H, hd).
    """
    nb, tb, B, KV, hd = kc.shape
    H = q.shape[1]
    G = H // KV
    qf = q.astype(jnp.float32)
    k = kc.astype(jnp.float32).transpose(2, 3, 0, 1, 4).reshape(B, KV, nb * tb, hd)
    v = vc.astype(jnp.float32).transpose(2, 3, 0, 1, 4).reshape(B, KV, nb * tb, hd)
    k = jnp.repeat(k, G, axis=1)           # (B, H, T, hd)
    v = jnp.repeat(v, G, axis=1)
    s = jnp.einsum("bhd,bhtd->bht", qf, k) * (hd ** -0.5)
    mask = jnp.arange(nb * tb) < valid_len
    s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bht,bhtd->bhd", p, v)
    return o.astype(q.dtype)
