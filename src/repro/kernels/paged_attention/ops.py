"""jit'd public wrapper for the paged decode-attention kernel."""
from __future__ import annotations

from functools import partial

import jax

from .kernel import paged_attention_fwd
from .ref import paged_attention_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@partial(jax.jit, static_argnames=("use_kernel",))
def paged_attention(q, kc, vc, valid_len, *, use_kernel: bool = True):
    """q: (B, H, hd); kc/vc: (nb, tb, B, KV, hd) -> (B, H, hd)."""
    if not use_kernel:
        return paged_attention_ref(q, kc, vc, valid_len)
    return paged_attention_fwd(q, kc, vc, valid_len,
                               interpret=not _on_tpu())
