from .ops import leaf_probe, leaf_probe_batch, leaf_probe_np  # noqa: F401
from .ref import leaf_probe_ref, split64  # noqa: F401
