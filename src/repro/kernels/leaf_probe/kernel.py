"""Pallas TPU kernel for the batched ordered-index leaf probe — the
"leaf search" phase of SCAN (core/ordered.py) as a fleet-scale hot spot:
one invocation locates the covering leaf of EVERY client's scan start key
in a tick (fleet.locate_wave).

Shape of the problem: the fence table (leaf low keys, sorted) is small
metadata — a few thousand uint64s — while the start-key batch scales with
the fleet.  Both fit VMEM; the kernel tiles the key batch and keeps the
whole fence table resident per tile (the same residency pattern as the
race_lookup kernel's index).

64-bit keys on 32-bit lanes: inputs arrive pre-split into (hi, lo) uint32
halves; ``low <= start`` is the lexicographic pair compare.  The result
``count(lows <= start) - 1`` is an (BLOCK, M) compare-and-reduce on the
VPU — no gather, no MXU needed.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _probe_kernel(shi_ref, slo_ref, lhi_ref, llo_ref, idx_ref):
    shi = shi_ref[...]                                # (BK,)
    slo = slo_ref[...]
    lhi = lhi_ref[...]                                # (M,)
    llo = llo_ref[...]
    le = (lhi[None, :] < shi[:, None]) | (
        (lhi[None, :] == shi[:, None]) & (llo[None, :] <= slo[:, None]))
    idx_ref[...] = jnp.sum(le.astype(jnp.int32), axis=1) - 1


def leaf_probe_fwd(starts_hi, starts_lo, lows_hi, lows_lo, *,
                   block_keys: int = 256, interpret: bool = True):
    """starts: (N,) uint32 halves; lows: (M,) uint32 halves (sorted as
    uint64) -> (N,) int32 rightmost-low-<=-start indices (-1 = none)."""
    N = starts_hi.shape[0]
    M = lows_hi.shape[0]
    block_keys = min(block_keys, N)
    assert N % block_keys == 0
    return pl.pallas_call(
        functools.partial(_probe_kernel),
        grid=(N // block_keys,),
        in_specs=[
            pl.BlockSpec((block_keys,), lambda i: (i,)),
            pl.BlockSpec((block_keys,), lambda i: (i,)),
            pl.BlockSpec((M,), lambda i: (0,)),       # fence table resident
            pl.BlockSpec((M,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_keys,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((N,), jnp.int32),
        interpret=interpret,
    )(starts_hi, starts_lo, lows_hi, lows_lo)
