"""jit'd public wrapper for the leaf-probe kernel, plus the host-facing
**batched entry point** used by the simulator, the fleet engine, and the
serving backend.

``leaf_probe`` is the jitted device API (jnp in / jnp out, pre-split
hi/lo halves).  ``leaf_probe_batch`` is the shared entry point: uint64
numpy in / numpy out, pads the key batch to the kernel block size, and
routes through the Pallas kernel only on TPU — elsewhere it runs the
bit-exact numpy mirror (``core.ordered.leaf_probe_np``, a uint64
searchsorted; interpret-mode Pallas would recompile per shape on every
fleet tick whose fence table grew).
"""
from __future__ import annotations

from functools import partial

import jax
import numpy as np

from repro.core.ordered import leaf_probe_np  # noqa: F401  (re-export)

from .kernel import leaf_probe_fwd
from .ref import leaf_probe_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@partial(jax.jit, static_argnames=("use_kernel", "block_keys"))
def leaf_probe(starts_hi, starts_lo, lows_hi, lows_lo, *,
               block_keys: int = 256, use_kernel: bool = True):
    """Batched leaf probe on pre-split uint32 halves -> (N,) int32."""
    if not use_kernel:
        return leaf_probe_ref(starts_hi, starts_lo, lows_hi, lows_lo)
    return leaf_probe_fwd(starts_hi, starts_lo, lows_hi, lows_lo,
                          block_keys=block_keys, interpret=not _on_tpu())


def leaf_probe_batch(starts: np.ndarray, lows: np.ndarray, *,
                     block_keys: int = 256,
                     prefer_kernel: bool = None) -> np.ndarray:
    """Shared entry point: locate the rightmost ``lows`` entry <= each
    start key.  ``starts`` (N,) uint64, ``lows`` (M,) uint64 sorted
    ascending; returns (N,) int32 (-1 = every low exceeds the start).

    One invocation serves a whole fleet tick's scans — callers
    (core/fleet.py locate_wave, core/api.py, serving/backend.py)
    concatenate every client's start keys before calling."""
    starts = np.ascontiguousarray(starts, np.uint64)
    lows = np.ascontiguousarray(lows, np.uint64)
    if prefer_kernel is None:
        prefer_kernel = _on_tpu()
    if prefer_kernel and len(lows):
        try:
            import jax.numpy as jnp
            n = len(starts)
            pad = -(-max(n, 1) // block_keys) * block_keys - n
            sp = np.concatenate([starts, np.zeros(pad, np.uint64)])
            shi = jnp.asarray((sp >> 32).astype(np.uint32))
            slo = jnp.asarray((sp & 0xFFFFFFFF).astype(np.uint32))
            lhi = jnp.asarray((lows >> 32).astype(np.uint32))
            llo = jnp.asarray((lows & 0xFFFFFFFF).astype(np.uint32))
            idx = leaf_probe(shi, slo, lhi, llo, block_keys=block_keys)
            return np.asarray(idx[:n], np.int32)
        except Exception:       # pragma: no cover - jax-less fallback
            pass
    return leaf_probe_np(starts, lows)
