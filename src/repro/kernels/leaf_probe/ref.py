"""Pure-jnp oracle for the vectorized ordered-index leaf probe.

The ordered keydir (core/ordered.py) locates the covering leaf of a scan
start key as "the rightmost leaf whose low fence <= start" over the
sorted fence table.  Keys are 64-bit; TPU vector lanes are 32-bit, so
both oracle and kernel operate on (hi, lo) uint32 pairs compared
lexicographically — bit-exact with the numpy mirror
(``core.ordered.leaf_probe_np``, a uint64 searchsorted).
"""
from __future__ import annotations

import jax.numpy as jnp


def split64(x):
    """uint64 -> (hi, lo) uint32 pair (works on traced jnp arrays)."""
    x = x.astype(jnp.uint64)
    return ((x >> jnp.uint64(32)).astype(jnp.uint32),
            (x & jnp.uint64(0xFFFFFFFF)).astype(jnp.uint32))


def leaf_probe_ref(starts_hi, starts_lo, lows_hi, lows_lo):
    """starts: (N,) uint32 pair; lows: (M,) uint32 pair, sorted ascending
    as uint64.  Returns (N,) int32: index of the rightmost low <= start,
    -1 when every low exceeds the start.

    count(lows <= start) - 1, computed as an (N, M) lexicographic
    comparison reduced over M — gather-free, VPU-friendly.
    """
    le = (lows_hi[None, :] < starts_hi[:, None]) | (
        (lows_hi[None, :] == starts_hi[:, None])
        & (lows_lo[None, :] <= starts_lo[:, None]))
    return jnp.sum(le.astype(jnp.int32), axis=1) - 1
