"""jit'd public wrapper for the fleet-tick read-sweep kernel, plus the
host-facing batched entry point.

``fleet_read`` is the jitted device API (jnp in / jnp out, pre-split
hi/lo slab planes).  ``fleet_read_sweep`` is the shared entry point:
uint64 numpy slab in / numpy rows out; it routes through the Pallas
kernel only on TPU — elsewhere it runs the bit-exact numpy gather.  The
numpy ``DMPool.exec_fused_tick`` stays **authoritative** on CPU (it is
the simulator's replay-oracle-checked engine); this twin covers the
READ sweep — the tick's only pure gather — for device offload.  The
mutating sweeps (WRITE/CAS/FAA) update host slab state and stay on the
host.
"""
from __future__ import annotations

from functools import partial

import jax
import numpy as np

from .kernel import fleet_read_fwd
from .ref import fleet_read_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@partial(jax.jit, static_argnames=("n", "use_kernel"))
def fleet_read(slab_hi, slab_lo, cells, offs, *, n: int,
               use_kernel: bool = True):
    """Uniform-length read sweep on pre-split uint32 slab planes ->
    ((N, n), (N, n)) uint32."""
    if not use_kernel:
        return fleet_read_ref(slab_hi, slab_lo, cells, offs, n=n)
    return fleet_read_fwd(slab_hi, slab_lo, cells, offs, n=n,
                          interpret=not _on_tpu())


def fleet_read_sweep(slab: np.ndarray, region_words: int,
                     cells: np.ndarray, offs: np.ndarray, n: int, *,
                     prefer_kernel: bool = None) -> np.ndarray:
    """Gather ``n`` contiguous uint64 words per verb from the flat slab.

    ``slab`` is the DMPool's flat uint64 buffer (``pool.slab.buf``),
    viewed as ``(n_cells, region_words)``; ``cells``/``offs`` are the
    per-verb cell indices and in-region word offsets (``n`` uniform —
    callers group verbs by length).  Returns (N, n) uint64 rows."""
    cells = np.ascontiguousarray(cells, np.int64)
    offs = np.ascontiguousarray(offs, np.int64)
    slab2d = slab.reshape(-1, region_words)
    if prefer_kernel is None:
        prefer_kernel = _on_tpu()
    if prefer_kernel and len(cells):
        try:
            import jax.numpy as jnp
            hi = jnp.asarray((slab2d >> np.uint64(32)).astype(np.uint32))
            lo = jnp.asarray((slab2d & np.uint64(0xFFFFFFFF))
                             .astype(np.uint32))
            rhi, rlo = fleet_read(hi, lo, jnp.asarray(cells, jnp.int32),
                                  jnp.asarray(offs, jnp.int32), n=n)
            return (np.asarray(rhi, np.uint64) << np.uint64(32)) \
                | np.asarray(rlo, np.uint64)
        except Exception:       # pragma: no cover - jax-less fallback
            pass
    return slab2d[cells[:, None], offs[:, None] + np.arange(n)]
