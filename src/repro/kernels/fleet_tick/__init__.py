from .ops import fleet_read, fleet_read_sweep  # noqa: F401
from .ref import fleet_read_ref  # noqa: F401
