"""Pallas TPU kernel for the fused fleet-tick READ sweep — the gather
half of ``DMPool.exec_fused_tick`` as a device twin.

The fused tick reads are a paged gather: every verb names a region
*cell* in the flat slab plus a word offset, and pulls ``n`` contiguous
words.  On TPU that is exactly the block-table pattern the paged
attention kernel uses: the cell indices are **scalar-prefetched**
(``pltpu.PrefetchScalarGridSpec``) so the DMA engine can route each grid
step's HBM->VMEM copy to the right slab row before the kernel body runs,
and the in-row slice is a cheap dynamic slice in VMEM.

64-bit words on 32-bit lanes: the slab arrives pre-split into (hi, lo)
uint32 planes of shape ``(n_cells, region_words)``; callers recombine
after the gather.  Verb lengths are uniform per call — the host groups
verbs by their (few, small) distinct lengths, mirroring how the numpy
sweep's ragged addressing collapses for uniform rows.

Grid: (N,) — one verb per step; the slab row stays in HBM and only the
selected row streams in per step.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _read_sweep_kernel(cells_ref, offs_ref, hi_ref, lo_ref,
                       ohi_ref, olo_ref, *, n):
    off = offs_ref[pl.program_id(0)]
    ohi_ref[0, :] = hi_ref[0, pl.ds(off, n)]
    olo_ref[0, :] = lo_ref[0, pl.ds(off, n)]


def fleet_read_fwd(slab_hi, slab_lo, cells, offs, *, n: int,
                   interpret: bool = True):
    """slab planes: (n_cells, region_words) uint32; cells/offs: (N,)
    int32; -> ((N, n) hi, (N, n) lo) uint32 gathered rows."""
    N = cells.shape[0]
    _n_cells, region_words = slab_hi.shape
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,                  # cells, offs
        grid=(N,),
        in_specs=[
            # DMA the verb's slab row, routed by the prefetched cell id
            pl.BlockSpec((1, region_words), lambda i, cells, offs:
                         (cells[i], 0)),
            pl.BlockSpec((1, region_words), lambda i, cells, offs:
                         (cells[i], 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, n), lambda i, cells, offs: (i, 0)),
            pl.BlockSpec((1, n), lambda i, cells, offs: (i, 0)),
        ],
    )
    return pl.pallas_call(
        functools.partial(_read_sweep_kernel, n=n),
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct((N, n), jnp.uint32),
                   jax.ShapeDtypeStruct((N, n), jnp.uint32)],
        interpret=interpret,
    )(cells.astype(jnp.int32), offs.astype(jnp.int32), slab_hi, slab_lo)
