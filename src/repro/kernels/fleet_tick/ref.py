"""Pure-jnp oracle for the fused fleet-tick READ sweep.

Same contract as the Pallas kernel: gather ``n`` contiguous words per
verb from the 2-D slab view ``(n_cells, region_words)``, operating on
(hi, lo) uint32 planes (64-bit slab words on 32-bit lanes).  The fancy
index below is the jnp transliteration of the numpy sweep's
repeat/cumsum addressing collapsed for uniform row lengths.
"""
from __future__ import annotations

import jax.numpy as jnp


def fleet_read_ref(slab_hi, slab_lo, cells, offs, *, n: int):
    """slab planes: (n_cells, region_words) uint32; cells/offs: (N,)
    int -> ((N, n) hi, (N, n) lo) uint32."""
    cols = offs[:, None].astype(jnp.int32) + jnp.arange(n, dtype=jnp.int32)
    rows = cells[:, None].astype(jnp.int32)
    return slab_hi[rows, cols], slab_lo[rows, cols]
