"""Pure-jnp oracle for the flash attention kernel (GQA, causal optional)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def flash_attention_ref(q, k, v, *, causal: bool = True):
    """q: (B, H, Sq, hd); k/v: (B, KV, Skv, hd) -> (B, H, Sq, hd).

    Dense reference: materializes the full score matrix in fp32.
    """
    B, H, Sq, hd = q.shape
    KV, Skv = k.shape[1], k.shape[2]
    G = H // KV
    qg = q.reshape(B, KV, G, Sq, hd)
    s = jnp.einsum("bkgqh,bksh->bkgqs", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) * (hd ** -0.5)
    if causal:
        mask = jnp.tril(jnp.ones((Sq, Skv), bool), k=Skv - Sq)
        s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bksh->bkgqh", p, v.astype(jnp.float32))
    return o.reshape(B, H, Sq, hd).astype(q.dtype)
