"""jit'd public wrapper for the flash-attention kernel.

On CPU (this container) the kernel executes in interpret mode; on TPU it
compiles to a fused Mosaic kernel.  ``use_kernel=False`` falls back to the
pure-jnp twin used by the dry-run lowering.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .kernel import flash_attention_fwd
from .ref import flash_attention_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@partial(jax.jit, static_argnames=("causal", "block_q", "block_kv",
                                   "use_kernel"))
def flash_attention(q, k, v, *, causal: bool = True, block_q: int = 256,
                    block_kv: int = 512, use_kernel: bool = True):
    """q: (B, H, Sq, hd); k/v: (B, KV, Skv, hd) -> (B, H, Sq, hd)."""
    if not use_kernel:
        return flash_attention_ref(q, k, v, causal=causal)
    return flash_attention_fwd(q, k, v, causal=causal, block_q=block_q,
                               block_kv=block_kv, interpret=not _on_tpu())
