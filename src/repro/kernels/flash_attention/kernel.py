"""Pallas TPU flash-attention kernel (forward).

Grid: (batch * q_heads, Sq / BLOCK_Q).  Each program streams the KV
sequence in BLOCK_KV tiles through VMEM, carrying the online-softmax state
(m, l, acc) in VMEM scratch.  GQA is handled in the BlockSpec index maps: a
query head h reads kv head h // (H // KV) — no repeated KV in HBM.

Block shapes are MXU-aligned: BLOCK_Q x head_dim and BLOCK_KV x head_dim
tiles keep the two matmuls (q @ k^T and p @ v) on 128-multiple dims.
Causal masking is computed from absolute positions (program ids), and fully
-masked KV tiles are skipped via ``when`` predication on the tile index.

VMEM working set (defaults BQ=256, BK=512, hd=128, bf16):
    q 64KB + k/v 256KB + acc/m/l fp32 ~160KB + panel 512KB  <  ~1.2MB  OK
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _fwd_kernel(q_ref, k_ref, v_ref, o_ref, *, scale, block_q, block_kv,
                causal, seq_kv):
    qi = pl.program_id(1)                       # q-tile index
    nkv = seq_kv // block_kv

    q = q_ref[...].astype(jnp.float32) * scale  # (BQ, hd)

    def body(kv_i, carry):
        m_prev, l_prev, acc = carry
        k = pl.load(k_ref, (pl.dslice(kv_i * block_kv, block_kv),
                            slice(None))).astype(jnp.float32)
        v = pl.load(v_ref, (pl.dslice(kv_i * block_kv, block_kv),
                            slice(None))).astype(jnp.float32)
        s = q @ k.T                              # (BQ, BKV) on the MXU
        if causal:
            q_pos = qi * block_q + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_kv), 0)
            k_pos = kv_i * block_kv + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_kv), 1)
            s = jnp.where(q_pos >= k_pos, s, NEG_INF)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_new = l_prev * alpha + jnp.sum(p, axis=1)
        acc = acc * alpha[:, None] + p @ v
        return m_new, l_new, acc

    m0 = jnp.full((block_q,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((block_q,), jnp.float32)
    acc0 = jnp.zeros((block_q, q_ref.shape[-1]), jnp.float32)

    if causal:
        # skip KV tiles strictly above the diagonal
        last = jnp.minimum(nkv, (qi + 1) * block_q // block_kv
                           + (1 if block_q % block_kv else 0) + 1)
        upper = jnp.minimum(last, nkv)
    else:
        upper = nkv
    m, l, acc = jax.lax.fori_loop(0, upper, body, (m0, l0, acc0))
    o_ref[...] = (acc / jnp.maximum(l, 1e-30)[:, None]).astype(o_ref.dtype)


def flash_attention_fwd(q, k, v, *, causal: bool = True, block_q: int = 256,
                        block_kv: int = 512, interpret: bool = True):
    """q: (B, H, Sq, hd); k/v: (B, KV, Skv, hd) -> (B, H, Sq, hd)."""
    B, H, Sq, hd = q.shape
    KV, Skv = k.shape[1], k.shape[2]
    G = H // KV
    block_q = min(block_q, Sq)
    block_kv = min(block_kv, Skv)
    assert Sq % block_q == 0 and Skv % block_kv == 0
    scale = hd ** -0.5
    grid = (B * H, Sq // block_q)

    kernel = functools.partial(_fwd_kernel, scale=scale, block_q=block_q,
                               block_kv=block_kv, causal=causal, seq_kv=Skv)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, None, block_q, hd),
                         lambda bh, qi: (bh // H, bh % H, qi, 0)),
            pl.BlockSpec((None, None, Skv, hd),
                         lambda bh, qi: (bh // H, (bh % H) // G, 0, 0)),
            pl.BlockSpec((None, None, Skv, hd),
                         lambda bh, qi: (bh // H, (bh % H) // G, 0, 0)),
        ],
        out_specs=pl.BlockSpec((None, None, block_q, hd),
                               lambda bh, qi: (bh // H, bh % H, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, hd), q.dtype),
        interpret=interpret,
    )(q, k, v)
