"""Sharded, atomic, async checkpointing with elastic restore."""
from .checkpoint import (CheckpointManager, load_checkpoint,  # noqa: F401
                         save_checkpoint)
