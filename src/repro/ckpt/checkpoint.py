"""Sharded checkpointing: npz-per-leaf-group + JSON manifest.

Design (what a 1000-node deployment needs, scaled to this repo):

* **Sharded write**: every leaf is saved independently (chunked by leading
  axis into ``shards`` files) so N hosts can each write their slice; here a
  thread pool stands in for the host fleet.
* **Atomic commit**: writes go to ``step_XXXX.tmp/``; a manifest (pytree
  structure, shapes, dtypes, shard layout, step, data-pipeline cursor) is
  written last and the directory is atomically renamed.  A crash mid-save
  leaves the previous checkpoint intact; ``latest()`` only ever sees
  committed directories.
* **Async save**: ``save_async`` snapshots device arrays to host (blocking
  only for D2H) and writes in a background thread — the train loop continues.
* **Elastic restore**: the manifest stores *logical* arrays; ``load`` reads
  and reassembles full arrays then re-shards onto the *current* mesh, so a
  job can restart on a different topology (e.g. 256 -> 512 chips) — the
  dry-run's multi-pod mesh can load a single-pod checkpoint.
* **Integrity**: per-file crc32 recorded in the manifest and verified on
  load (bit-rot / torn-write detection).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import zlib
from concurrent.futures import ThreadPoolExecutor
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

MANIFEST = "manifest.json"


def _leaf_paths(tree) -> List[Tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        name = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                        for k in path)
        out.append((name, leaf))
    return out


def _crc(arr: np.ndarray) -> int:
    return zlib.crc32(np.ascontiguousarray(arr).tobytes())


def save_checkpoint(ckpt_dir: str, step: int, tree, *, extra: Dict = None,
                    shards: int = 4, workers: int = 8) -> str:
    """Synchronous sharded save with atomic commit.  Returns final path."""
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    host_tree = jax.tree.map(lambda x: np.asarray(x), tree)
    leaves = _leaf_paths(host_tree)
    manifest: Dict[str, Any] = {"step": step, "extra": extra or {},
                                "leaves": {}}

    def write_leaf(item):
        name, arr = item
        arr = np.asarray(arr)
        logical_dtype = str(arr.dtype)
        if arr.dtype.kind == "V" or "bfloat16" in logical_dtype:
            # numpy cannot round-trip ml_dtypes (bf16 etc.): store the raw
            # bits as uint16 and record the logical dtype in the manifest
            arr = arr.view(np.uint16)
        fname = name.replace("/", "__")
        entries = []
        if arr.ndim >= 1 and arr.shape[0] >= shards and arr.nbytes > 1 << 20:
            chunks = np.array_split(arr, shards, axis=0)
            for i, ch in enumerate(chunks):
                f = f"{fname}.shard{i}.npy"
                np.save(os.path.join(tmp, f), ch)
                entries.append({"file": f, "crc": _crc(ch),
                                "rows": int(ch.shape[0])})
        else:
            f = f"{fname}.npy"
            np.save(os.path.join(tmp, f), arr)
            entries.append({"file": f, "crc": _crc(arr),
                            "rows": int(arr.shape[0]) if arr.ndim else -1})
        return name, {"shape": list(arr.shape), "dtype": logical_dtype,
                      "shards": entries}

    with ThreadPoolExecutor(max_workers=workers) as ex:
        for name, meta in ex.map(write_leaf, leaves):
            manifest["leaves"][name] = meta

    with open(os.path.join(tmp, MANIFEST), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)  # atomic commit
    return final


def load_checkpoint(ckpt_dir: str, tree_like, *, step: Optional[int] = None,
                    mesh=None, specs=None, verify: bool = True):
    """Load (latest or specific step) and re-shard onto ``mesh``+``specs``.

    ``tree_like``: a pytree with the target structure (abstract ok).
    Returns (tree, step, extra).
    """
    path = (os.path.join(ckpt_dir, f"step_{step:08d}") if step is not None
            else latest(ckpt_dir))
    assert path is not None, f"no checkpoint in {ckpt_dir}"
    with open(os.path.join(path, MANIFEST)) as f:
        manifest = json.load(f)
    names = dict(_leaf_paths(tree_like))

    def read_leaf(name):
        meta = manifest["leaves"][name]
        parts = []
        for e in meta["shards"]:
            arr = np.load(os.path.join(path, e["file"]))
            if verify and _crc(arr) != e["crc"]:
                raise IOError(f"checksum mismatch in {e['file']}")
            parts.append(arr)
        full = parts[0] if len(parts) == 1 else np.concatenate(parts, axis=0)
        if "bfloat16" in meta["dtype"] and full.dtype == np.uint16:
            import ml_dtypes
            full = full.view(ml_dtypes.bfloat16)
        assert list(full.shape) == meta["shape"], (name, full.shape)
        return full

    flat_names = [n for n, _ in _leaf_paths(tree_like)]
    with ThreadPoolExecutor(max_workers=8) as ex:
        arrays = list(ex.map(read_leaf, flat_names))

    treedef = jax.tree_util.tree_structure(tree_like)
    loaded = jax.tree_util.tree_unflatten(treedef, arrays)
    # restore dtypes (npz preserves them; bf16 survives via ml_dtypes)
    loaded = jax.tree.map(
        lambda ref, arr: jnp.asarray(arr, dtype=ref.dtype), tree_like, loaded)
    if mesh is not None and specs is not None:
        from jax.sharding import NamedSharding
        loaded = jax.tree.map(
            lambda x, s: jax.device_put(x, NamedSharding(mesh, s)),
            loaded, specs)
    return loaded, manifest["step"], manifest.get("extra", {})


def latest(ckpt_dir: str) -> Optional[str]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = sorted(d for d in os.listdir(ckpt_dir)
                   if d.startswith("step_") and not d.endswith(".tmp")
                   and os.path.exists(os.path.join(ckpt_dir, d, MANIFEST)))
    return os.path.join(ckpt_dir, steps[-1]) if steps else None


class CheckpointManager:
    """Async saves + retention.  ``save_async`` returns immediately after the
    device->host snapshot; the write happens on a background thread."""

    def __init__(self, ckpt_dir: str, keep: int = 3):
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        self._pending: Optional[threading.Thread] = None
        self.saved_steps: List[int] = []

    def save_async(self, step: int, tree, extra: Dict = None):
        self.wait()
        host_tree = jax.tree.map(lambda x: np.asarray(x), tree)  # D2H snapshot

        def work():
            save_checkpoint(self.ckpt_dir, step, host_tree, extra=extra)
            self._gc()

        self._pending = threading.Thread(target=work, daemon=True)
        self._pending.start()
        self.saved_steps.append(step)

    def wait(self):
        if self._pending is not None:
            self._pending.join()
            self._pending = None

    def _gc(self):
        steps = sorted(d for d in os.listdir(self.ckpt_dir)
                       if d.startswith("step_") and not d.endswith(".tmp"))
        for d in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.ckpt_dir, d), ignore_errors=True)

    def latest(self) -> Optional[str]:
        return latest(self.ckpt_dir)
