import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512")
# ^ MUST run before any other import: jax locks the device count on first
#   initialization.  The dry-run (and ONLY the dry-run) needs 512 placeholder
#   devices to build the production mesh.

"""Multi-pod dry-run: lower + compile every (architecture x input-shape)
cell on the production meshes, prove memory fit, and extract the roofline
terms from the compiled artifact.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch llama3-8b \
        --shape train_4k --mesh single
    PYTHONPATH=src python -m repro.launch.dryrun --all --out artifacts/

Per cell this produces (artifacts/<arch>__<shape>__<mesh>.json):
    memory_analysis   bytes per device (argument/temp/output)
    cost_analysis     XLA's per-device flops/bytes (body-once; see
                      hlo_analysis for trip-count-corrected totals)
    roofline          compute / memory / collective terms in seconds
    collectives       per-kind wire bytes
"""
import argparse
import dataclasses
import json
import sys
import time
import traceback
from functools import partial
from typing import Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import base as C
from repro.launch import hlo_analysis as H
from repro.launch.mesh import (HBM_BW, ICI_BW, PEAK_FLOPS_BF16,
                               make_production_mesh)
from repro.models import (batch_specs, build, input_specs, param_stats,
                          pick_rules)
from repro.models.sharding import MeshRules
from repro.optim import OptConfig, Optimizer
from repro.train.trainer import make_train_step, pick_microbatches

V5E_HBM_PER_CHIP = 16e9


def _ns(mesh, spec):
    return NamedSharding(mesh, spec)


def lower_cell(cfg: C.ArchConfig, shape: C.ShapeSpec, mesh,
               rules: Optional[MeshRules] = None,
               n_micro: Optional[int] = None):
    """Lower + compile one cell; returns (compiled, model, meta)."""
    rules = rules or pick_rules(cfg, shape, mesh)
    model = build(cfg, mesh, rules)
    specs = input_specs(model, shape)
    bspecs = batch_specs(model, shape)
    pspecs = model.param_specs()
    t0 = time.time()  # lint: allow-nondet (compile wall-clock metering only)

    if shape.kind == "train":
        opt = Optimizer(OptConfig(moments=cfg.opt_moments))
        if n_micro is None:
            n_micro = pick_microbatches(model, shape.global_batch,
                                        shape.seq_len)
        step = make_train_step(model, opt, n_micro=n_micro)
        params_abs = model.abstract_params()
        opt_abs = jax.eval_shape(opt.init, params_abs)
        state_abs = {"params": params_abs, "opt": opt_abs}
        state_specs = {"params": pspecs, "opt": opt.state_specs(pspecs)}
        with mesh:
            lowered = jax.jit(
                step,
                in_shardings=(jax.tree.map(lambda s: _ns(mesh, s), state_specs,
                                           is_leaf=_is_spec),
                              jax.tree.map(lambda s: _ns(mesh, s), bspecs,
                                           is_leaf=_is_spec)),
                donate_argnums=0,
            ).lower(state_abs, specs)
            compiled = lowered.compile()
        meta = {"step": "train_step", "n_micro": n_micro}
    elif shape.kind == "prefill":
        params_abs = model.abstract_params()

        def prefill_step(params, batch):
            return model.prefill(params, batch["tokens"],
                                 frames=batch.get("frames"))

        with mesh:
            lowered = jax.jit(
                prefill_step,
                in_shardings=(jax.tree.map(lambda s: _ns(mesh, s), pspecs,
                                           is_leaf=_is_spec),
                              jax.tree.map(lambda s: _ns(mesh, s), bspecs,
                                           is_leaf=_is_spec)),
            ).lower(params_abs, specs)
            compiled = lowered.compile()
        meta = {"step": "prefill"}
    else:  # decode / long-decode: serve_step
        params_abs = model.abstract_params()

        def serve_step(params, cache, token):
            return model.decode_step(params, cache, token)

        with mesh:
            lowered = jax.jit(
                serve_step,
                in_shardings=(jax.tree.map(lambda s: _ns(mesh, s), pspecs,
                                           is_leaf=_is_spec),
                              jax.tree.map(lambda s: _ns(mesh, s),
                                           bspecs["cache"], is_leaf=_is_spec),
                              _ns(mesh, bspecs["token"])),
                donate_argnums=1,
            ).lower(params_abs, specs["cache"], specs["token"])
            compiled = lowered.compile()
        meta = {"step": "serve_step"}
    meta["compile_s"] = round(time.time() - t0, 1)  # lint: allow-nondet (compile wall-clock metering only)
    meta["fallbacks"] = [
        (str(a), int(b) if b else None, list(c))
        for a, b, c in model.resolver.fallbacks]
    return compiled, model, meta


def _is_spec(x):
    return isinstance(x, P)


def panel_hints(cfg: C.ArchConfig, shape: C.ShapeSpec):
    """Trailing-dim pairs of tensors the Pallas kernels keep in VMEM
    (attention score panels, SSD/mLSTM chunk masks) — see hlo_analysis."""
    hints = set()
    if shape.kind in ("train", "prefill"):
        S = shape.seq_len
        qc = min(cfg.attn_chunk_q, S)
        while S % qc:
            qc //= 2
        hints |= {(qc, S), (S, qc)}
        if cfg.enc_dec:
            e = cfg.enc_seq
            qe = min(cfg.attn_chunk_q, e)
            while e % qe:
                qe //= 2
            hints |= {(qe, e), (e, qe), (qc, e), (e, qc)}
        if cfg.ssm is not None:
            c = min(cfg.ssm.chunk, S)
            hints.add((c, c))
    return sorted(hints)


def analyze_cell(compiled, model, mesh, shape: C.ShapeSpec, meta: Dict
                 ) -> Dict:
    ma = compiled.memory_analysis()
    ca = compiled.cost_analysis() or {}
    costs = H.analyze(compiled.as_text(),
                      panel_dims=panel_hints(model.cfg, shape))
    n_chips = mesh.devices.size
    terms = H.roofline(costs, peak_flops=PEAK_FLOPS_BF16, hbm_bw=HBM_BW,
                       ici_bw=ICI_BW)
    stats = param_stats(model)
    # MODEL_FLOPS: 6*N*D for train (fwd+bwd), 2*N*D forward-only; decode D=new
    # tokens.  N excludes embeddings (active params for MoE).
    D = shape.global_batch * (shape.seq_len if shape.kind == "train"
                              else (shape.seq_len if shape.kind == "prefill"
                                    else 1))
    N = stats["active_non_embed"]
    model_flops = (6 if shape.kind == "train" else 2) * N * D
    useful = model_flops / max(costs.flops * n_chips, 1.0)
    bytes_per_dev = (ma.argument_size_in_bytes + ma.temp_size_in_bytes
                     + ma.output_size_in_bytes - ma.alias_size_in_bytes)
    # model-derived state floor: params (+cache) at declared dtypes under
    # the resolved shardings — the honest TPU-side residency, free of the
    # CPU backend's f32-normalization copies that inflate temp_bytes.
    pspecs = model.param_specs()
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))

    def sharded_bytes(leaf, spec):
        n = 1
        for d in leaf.shape:
            n *= d
        denom = 1
        for part in spec:
            for ax in ((part,) if isinstance(part, str) else (part or ())):
                denom *= sizes.get(ax, 1)
        return n * leaf.dtype.itemsize / denom

    state_floor = sum(jax.tree.leaves(jax.tree.map(
        sharded_bytes, model.abstract_params(), pspecs,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))))
    return {
        "memory": {
            "argument_bytes": ma.argument_size_in_bytes,
            "output_bytes": ma.output_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
            "alias_bytes": ma.alias_size_in_bytes,
            "per_device_bytes": bytes_per_dev,
            "param_floor_bytes": state_floor,
            "fits_v5e_16g": bool(bytes_per_dev <= V5E_HBM_PER_CHIP),
        },
        "xla_cost_analysis": {k: ca.get(k) for k in
                              ("flops", "bytes accessed") if k in ca},
        "roofline": terms,
        "collectives": {k: v for k, v in costs.coll_by_kind.items()},
        "collective_counts": {k: v for k, v in costs.n_collectives.items()},
        "top_collectives": dict(sorted(costs.coll_by_shape.items(),
                                       key=lambda kv: -kv[1])[:8]),
        "top_hbm": dict(sorted(costs.hbm_by_shape.items(),
                               key=lambda kv: -kv[1])[:8]),
        "model_flops": model_flops,
        "useful_flops_ratio": useful,
        "params": stats,
        "n_chips": n_chips,
        **meta,
    }


def run_cell(arch: str, shape_name: str, mesh_kind: str,
             rules: Optional[MeshRules] = None,
             n_micro: Optional[int] = None) -> Dict:
    cfg = C.get(arch)
    shape = {s.name: s for s in C.ALL_SHAPES}[shape_name]
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    compiled, model, meta = lower_cell(cfg, shape, mesh, rules, n_micro)
    out = analyze_cell(compiled, model, mesh, shape, meta)
    out.update({"arch": arch, "shape": shape_name, "mesh": mesh_kind})
    return out


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="artifacts")
    ap.add_argument("--quiet", action="store_true")
    args = ap.parse_args(argv)

    cells = []
    if args.all:
        for cfg, shape in C.cells():
            for mesh_kind in ("single", "multi"):
                cells.append((cfg.arch_id, shape.name, mesh_kind))
    else:
        assert args.arch and args.shape
        cells.append((args.arch, args.shape, args.mesh))

    os.makedirs(args.out, exist_ok=True)
    failures = []
    for arch, shape, mesh_kind in cells:
        tag = f"{arch}__{shape}__{mesh_kind}"
        try:
            res = run_cell(arch, shape, mesh_kind)
            with open(os.path.join(args.out, tag + ".json"), "w") as f:
                json.dump(res, f, indent=1, default=float)
            r = res["roofline"]
            print(f"OK   {tag:60s} compile={res['compile_s']:6.1f}s "
                  f"mem/dev={res['memory']['per_device_bytes']/1e9:7.2f}GB "
                  f"bottleneck={r['bottleneck']:10s} "
                  f"t=({r['t_compute']:.2e},{r['t_memory']:.2e},"
                  f"{r['t_collective']:.2e})s", flush=True)
        except Exception as e:
            failures.append((tag, repr(e)))
            print(f"FAIL {tag}: {e!r}", flush=True)
            if not args.quiet:
                traceback.print_exc()
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for t, e in failures:
            print(" ", t, e)
        sys.exit(1)
    print(f"\nall {len(cells)} cells passed")


if __name__ == "__main__":
    main()
