"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so that
importing this module never touches jax device state — the dry-run must set
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` *before* first jax
initialization, and smoke tests must see 1 device.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax


def _compat_make_mesh(shape, axes, devices=None):
    """``jax.make_mesh`` across jax versions.

    ``jax.sharding.AxisType`` (and make_mesh's ``axis_types`` kwarg) only
    exist from jax 0.5.x; on older versions every axis is implicitly Auto,
    so simply omitting the kwarg is the exact same mesh.
    """
    kwargs = {}
    if devices is not None:
        kwargs["devices"] = devices
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        try:
            return jax.make_mesh(shape, axes, **kwargs,
                                 axis_types=(axis_type.Auto,) * len(axes))
        except TypeError:           # AxisType exists but make_mesh predates it
            pass
    return jax.make_mesh(shape, axes, **kwargs)


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (data=16, model=16) = 256 chips.
    Multi-pod: (pod=2, data=16, model=16) = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _compat_make_mesh(shape, axes)


def make_host_mesh(shape: Tuple[int, ...] = (1, 1),
                   axes: Tuple[str, ...] = ("data", "model")):
    """Small mesh over however many (host) devices exist — tests/examples."""
    n = 1
    for s in shape:
        n *= s
    devs = jax.devices()[:n]
    return _compat_make_mesh(shape, axes, devices=devs)


# TPU v5e hardware constants (roofline targets; per assignment)
PEAK_FLOPS_BF16 = 197e12        # per chip
HBM_BW = 819e9                  # bytes/s per chip
ICI_BW = 50e9                   # bytes/s per link
