"""Launchers: production mesh, multi-pod dry-run, HLO roofline analysis."""
