"""Roofline-term extraction from compiled (post-SPMD) HLO.

XLA's ``compiled.cost_analysis()`` on this backend (a) reports *per-device*
numbers and (b) counts every ``while`` body ONCE regardless of trip count.
This module re-derives totals by parsing ``compiled.as_text()``:

* computations are parsed into op lists (shapes, operands, metadata);
* ``while`` trip counts are recovered from the loop-condition comparison
  constant (scan-lowered loops compare an induction variable against a
  literal);
* FLOPs: every ``dot`` (2 * |output| * contracted size), multiplied through
  the enclosing while/fusion/call chain;
* HBM bytes: per *kernel* (top-level op in a scheduled computation) as
  operand bytes + output bytes — fusions count their boundary, not their
  internals, matching how fused kernels touch HBM once;
* collective bytes: per-device wire traffic per op kind (ring model:
  all-reduce 2x shard bytes, all-gather/reduce-scatter 1x, all-to-all 1x,
  collective-permute 1x), times trip counts.

Validated against an unrolled-vs-scanned differential test (tests/).
"""
from __future__ import annotations

import math
import re
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_NAME_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*")
_KIND_RE = re.compile(r"\s*([\w\-]+)\(")


def _parse_op_line(line: str):
    """'%n = <type> kind(operands), attrs' -> (name, type, kind, rest).

    Tuple types contain parens and even '=' (in /*index=N*/ comments), so
    the type is skipped with a paren balance counter, not a regex.
    """
    m = _NAME_RE.match(line)
    if not m:
        return None
    name = m.group(1)
    i = m.end()
    n = len(line)
    if i < n and line[i] == "(":          # tuple-typed op
        depth = 0
        j = i
        while j < n:
            if line[j] == "(":
                depth += 1
            elif line[j] == ")":
                depth -= 1
                if depth == 0:
                    break
            j += 1
        type_str = line[i:j + 1]
        tail = line[j + 1:]
    else:                                  # scalar/array type
        mk = _KIND_RE.match(line, i)
        # the "type" for array ops sits between '=' and the op kind; find the
        # kind as the last word before '(' in the head segment
        head_end = line.find("(", i)
        if head_end < 0:
            return None
        head = line[i:head_end]
        parts = head.rsplit(None, 1)
        if len(parts) == 2:
            type_str, kind = parts
        else:
            type_str, kind = "", parts[0] if parts else ""
        rest = line[head_end + 1:]
        return name, type_str.strip(), kind.strip(), rest
    mk = _KIND_RE.match(tail)
    if not mk:
        return None
    kind = mk.group(1)
    rest = tail[mk.end():]
    return name, type_str, kind, rest
_CALLS_RE = re.compile(r"calls=%?([\w.\-]+)")
_COND_BODY_RE = re.compile(r"condition=%?([\w.\-]+),\s*body=%?([\w.\-]+)")
_CONST_RE = re.compile(r"constant\((\d+)\)")
_DIRECTION_RE = re.compile(r"direction=(\w+)")


def shape_bytes(shape_str: str) -> int:
    """Total bytes of possibly-tuple shape string."""
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.groups()
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def shape_elems(shape_str: str) -> int:
    m = _SHAPE_RE.search(shape_str)
    if not m:
        return 0
    n = 1
    for d in m.group(2).split(","):
        if d:
            n *= int(d)
    return n


@dataclass
class Op:
    name: str
    out_shape: str
    kind: str
    rest: str           # operand list + attributes (raw tail)


@dataclass
class Computation:
    name: str
    ops: List[Op] = field(default_factory=list)
    defs: Dict[str, Op] = field(default_factory=dict)


def parse_hlo(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for line in text.splitlines():
        s = line.strip()
        header = re.match(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\((.*)\)\s*->.*{", s)
        if header and not s.startswith("//"):
            cur = Computation(header.group(1))
            comps[cur.name] = cur
            continue
        if cur is None:
            continue
        if s.startswith("}"):
            cur = None
            continue
        parsed = _parse_op_line(line)
        if parsed:
            name, shape, kind, rest = parsed
            op = Op(name, shape.strip(), kind, rest)
            cur.ops.append(op)
            cur.defs[name] = op
    return comps


def _dot_flops(op: Op, comp: Computation, comps) -> float:
    """2 * |out| * contracted-size for a dot op."""
    out = shape_elems(op.out_shape)
    # contracting dims of lhs: shapes of operands come from defs or params
    mm = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", op.rest)
    operand_names = re.findall(r"%([\w.\-]+)", op.rest)
    if not operand_names:
        return 0.0
    lhs_shape = _shape_of(operand_names[0], comp, comps)
    if lhs_shape is None:
        return 2.0 * out  # unknown; degrade gracefully
    dims = [int(d) for d in mm.group(1).split(",") if d] if mm else []
    csize = 1
    for d in dims:
        if d < len(lhs_shape):
            csize *= lhs_shape[d]
    return 2.0 * out * max(csize, 1)


_param_shape_cache: Dict[Tuple[str, str], Optional[List[int]]] = {}


def _shape_of(name: str, comp: Computation, comps) -> Optional[List[int]]:
    op = comp.defs.get(name)
    if op is None:
        return None
    m = _SHAPE_RE.search(op.out_shape)
    if not m:
        return None
    return [int(d) for d in m.group(2).split(",") if d]


def trip_count(cond: Computation) -> int:
    """Recover the scan trip count from the loop condition computation."""
    consts = []
    direction = "LT"
    for op in cond.ops:
        for m in _CONST_RE.finditer(op.kind + "(" + op.rest):
            consts.append(int(m.group(1)))
        md = _DIRECTION_RE.search(op.rest)
        if md:
            direction = md.group(1)
    if not consts:
        return 1
    n = max(consts)
    return n + 1 if direction == "LE" else n


COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")


@dataclass
class Costs:
    flops: float = 0.0
    hbm_bytes: float = 0.0          # kernelized: VMEM panels discounted
    hbm_bytes_raw: float = 0.0      # every kernel boundary counted
    coll_bytes: float = 0.0
    coll_by_kind: Dict[str, float] = field(default_factory=lambda: defaultdict(float))
    n_collectives: Dict[str, int] = field(default_factory=lambda: defaultdict(int))
    coll_by_shape: Dict[str, float] = field(default_factory=lambda: defaultdict(float))
    hbm_by_shape: Dict[str, float] = field(default_factory=lambda: defaultdict(float))

    def scaled(self, k: float) -> "Costs":
        c = Costs(self.flops * k, self.hbm_bytes * k, self.hbm_bytes_raw * k,
                  self.coll_bytes * k)
        for kk, v in self.coll_by_kind.items():
            c.coll_by_kind[kk] = v * k
        for kk, v in self.n_collectives.items():
            c.n_collectives[kk] = int(v * k)
        for kk, v in self.coll_by_shape.items():
            c.coll_by_shape[kk] = v * k
        for kk, v in self.hbm_by_shape.items():
            c.hbm_by_shape[kk] = v * k
        return c

    def add(self, o: "Costs"):
        self.flops += o.flops
        self.hbm_bytes += o.hbm_bytes
        self.hbm_bytes_raw += o.hbm_bytes_raw
        self.coll_bytes += o.coll_bytes
        for kk, v in o.coll_by_kind.items():
            self.coll_by_kind[kk] += v
        for kk, v in o.n_collectives.items():
            self.n_collectives[kk] += v
        for kk, v in o.coll_by_shape.items():
            self.coll_by_shape[kk] += v
        for kk, v in o.hbm_by_shape.items():
            self.hbm_by_shape[kk] += v


def _operand_names(op: Op):
    head = op.rest.split("),")[0]
    return re.findall(r"%([\w.\-]+)", head)


def _operand_shapes(op: Op, comp: Computation):
    out = []
    for nm in _operand_names(op):
        d = comp.defs.get(nm)
        if d is not None:
            out.append(d.out_shape)
    return out


class Analyzer:
    """Walks the HLO call graph accumulating per-device roofline terms.

    ``panel_dims``: set of (d_minor2, d_minor1) trailing-dim pairs marking
    tensors that the Pallas kernels keep resident in VMEM (attention score
    panels, SSD chunk masks).  Their HBM traffic is discounted in
    ``hbm_bytes`` (and fully counted in ``hbm_bytes_raw``) — this is the
    documented "kernelized" memory model used by §Roofline.
    """

    def __init__(self, comps: Dict[str, Computation],
                 panel_dims=()):  # iterable of (dim-2, dim-1)
        self.comps = comps
        self.panel_dims = {tuple(p) for p in panel_dims}
        self.memo: Dict = {}

    # -- byte helpers -------------------------------------------------------
    def _bf16_origin(self, name: str, comp: Computation, depth: int = 4
                     ) -> bool:
        """True when an f32 value is a float-normalized bf16 tensor.

        XLA CPU has no bf16 matmul: its float-normalization pass upcasts
        bf16 dot operands to f32 and SPMD hoists the converts, so bf16
        weights/activations appear as f32 in the optimized HLO — 2x their
        real TPU footprint.  Detected by chasing convert/copy/slice/gather
        chains back to a bf16 value (or a fusion wrapping such a convert);
        byte accounting then uses the *logical* 2-byte width.
        """
        if depth == 0:
            return False
        op = comp.defs.get(name)
        if op is None or "f32" not in op.out_shape:
            return False
        if op.kind in ("convert", "copy", "bitcast", "all-gather",
                       "get-tuple-element", "dynamic-slice", "transpose",
                       "reshape", "all-reduce", "broadcast"):
            for nm in _operand_names(op):
                src = comp.defs.get(nm)
                if src is not None and "bf16" in src.out_shape:
                    return True
                if self._bf16_origin(nm, comp, depth - 1):
                    return True
            return False
        if op.kind == "fusion":
            mc = _CALLS_RE.search(op.rest)
            inner = self.comps.get(mc.group(1)) if mc else None
            if inner is not None:
                out_elems = shape_elems(op.out_shape)
                for iop in inner.ops:
                    if iop.kind == "convert" and "f32" in iop.out_shape:
                        for nm in _operand_names(iop):
                            src = inner.defs.get(nm)
                            if src is not None and "bf16" in src.out_shape \
                                    and shape_elems(src.out_shape) == out_elems:
                                return True
            # fusion of a hoisted entry convert: single bf16 param, f32 out
            for nm in _operand_names(op):
                src = comp.defs.get(nm)
                if src is not None and "bf16" in src.out_shape and \
                        shape_elems(src.out_shape) == shape_elems(op.out_shape):
                    return True
            return False
        return False

    _PURE_DATA_KINDS = frozenset((
        "convert", "copy", "bitcast", "parameter", "transpose", "reshape",
        "broadcast", "constant", "tuple", "get-tuple-element", "slice"))

    def _is_normalization_fusion(self, inner: Computation) -> bool:
        """A fusion that only converts/relabels a bf16 tensor to f32 is a
        float-normalization artifact of the CPU backend (TPU runs the dot in
        bf16 directly) — it contributes no HBM traffic on the target."""
        has_convert = False
        for op in inner.ops:
            if op.kind not in self._PURE_DATA_KINDS:
                return False
            if op.kind == "convert":
                has_convert = True
        return has_convert

    def _is_panel(self, shape_str: str) -> bool:
        if not self.panel_dims:
            return False
        for m in _SHAPE_RE.finditer(shape_str):
            dims = [int(d) for d in m.group(2).split(",") if d]
            if len(dims) >= 2 and (dims[-2], dims[-1]) in self.panel_dims:
                return True
        return False

    def _eff(self, shape_str: str, halve: bool = False) -> Tuple[int, int]:
        """(kernelized bytes, raw bytes) for one shape."""
        b = shape_bytes(shape_str)
        if halve:
            b //= 2
        return (0 if self._is_panel(shape_str) else b), b

    def _io_bytes(self, op: Op, comp: Computation) -> Tuple[int, int]:
        eff = raw = 0
        for nm in _operand_names(op):
            d = comp.defs.get(nm)
            if d is None:
                continue
            e, r = self._eff(d.out_shape, self._bf16_origin(nm, comp))
            eff += e
            raw += r
        e, r = self._eff(op.out_shape)
        return eff + e, raw + r

    def _slice_discount(self, inner: Computation) -> Tuple[int, int]:
        disc_e = disc_r = 0
        for op in inner.ops:
            if op.kind == "dynamic-slice":
                names = _operand_names(op)
                if names:
                    src = inner.defs.get(names[0])
                    if src is not None:
                        d = max(shape_bytes(src.out_shape)
                                - shape_bytes(op.out_shape), 0)
                        disc_r += d
                        if not self._is_panel(src.out_shape):
                            disc_e += d
            elif op.kind == "dynamic-update-slice":
                names = _operand_names(op)
                if len(names) >= 2:
                    upd = inner.defs.get(names[1])
                    ub = (shape_bytes(upd.out_shape) if upd is not None else 0)
                    d = 2 * max(shape_bytes(op.out_shape) - ub, 0)
                    disc_r += d
                    if not self._is_panel(op.out_shape):
                        disc_e += d
        return disc_e, disc_r

    def _collective(self, op: Op, comp: Computation) -> Tuple[str, float]:
        kind = op.kind[:-6] if op.kind.endswith("-start") else op.kind
        inb = outb = 0
        halve = False
        for nm in _operand_names(op):
            d = comp.defs.get(nm)
            if d is None:
                continue
            h = self._bf16_origin(nm, comp)
            halve |= h
            inb += shape_bytes(d.out_shape) // (2 if h else 1)
        outb = shape_bytes(op.out_shape) // (2 if halve else 1)
        if kind == "all-reduce":
            return kind, 2.0 * inb      # ring: reduce-scatter + all-gather
        if kind == "all-gather":
            return kind, float(max(outb - inb, inb))
        if kind == "reduce-scatter":
            return kind, float(inb)
        return kind, float(inb)          # all-to-all, collective-permute

    # -- main walk ----------------------------------------------------------
    def comp_costs(self, comp: Computation, *, as_fusion: bool) -> Costs:
        key = (comp.name, as_fusion)
        if key in self.memo:
            return self.memo[key]
        comps = self.comps
        c = Costs()

        def add_io(op):
            if not as_fusion:
                e, r = self._io_bytes(op, comp)
                c.hbm_bytes += e
                c.hbm_bytes_raw += r
                if e:
                    c.hbm_by_shape[f"{op.kind} {op.out_shape[:64]}"] += e

        for op in comp.ops:
            k = op.kind
            if k == "while":
                mcb = _COND_BODY_RE.search(op.rest)
                if mcb:
                    cond_name, body_name = mcb.groups()
                    trips = trip_count(comps[cond_name])
                    body = self.comp_costs(comps[body_name], as_fusion=False)
                    c.add(body.scaled(trips))
            elif k == "fusion":
                mc = _CALLS_RE.search(op.rest)
                inner_comp = comps.get(mc.group(1)) if mc else None
                if inner_comp is not None:
                    inner = self.comp_costs(inner_comp, as_fusion=True)
                    c.flops += inner.flops
                    c.coll_bytes += inner.coll_bytes
                    for kk, v in inner.coll_by_kind.items():
                        c.coll_by_kind[kk] += v
                    for kk, v in inner.coll_by_shape.items():
                        c.coll_by_shape[kk] += v
                    for kk, v in inner.n_collectives.items():
                        c.n_collectives[kk] += v
                if not as_fusion:
                    if inner_comp is not None and \
                            self._is_normalization_fusion(inner_comp):
                        continue  # CPU float-normalization artifact
                    e, r = self._io_bytes(op, comp)
                    if inner_comp is not None:
                        de, dr = self._slice_discount(inner_comp)
                        e -= de
                        r -= dr
                    c.hbm_bytes += max(e, 0)
                    c.hbm_bytes_raw += max(r, 0)
                    if e > 0:
                        c.hbm_by_shape[f"fusion {op.out_shape[:64]}"] += max(e, 0)
            elif k in ("call", "conditional", "async-start"):
                for nm in _CALLS_RE.finditer(op.rest):
                    if nm.group(1) in comps:
                        c.add(self.comp_costs(comps[nm.group(1)],
                                              as_fusion=as_fusion))
            elif k == "dot":
                c.flops += _dot_flops(op, comp, comps)
                add_io(op)
            elif k == "convolution":
                c.flops += 2.0 * shape_elems(op.out_shape)
                add_io(op)
            elif k in COLLECTIVES or (k.endswith("-start") and
                                      k[:-6] in COLLECTIVES):
                kind, b = self._collective(op, comp)
                c.coll_bytes += b
                c.coll_by_kind[kind] += b
                c.n_collectives[kind] += 1
                c.coll_by_shape[f"{kind} {op.out_shape[:64]}"] += b
            elif k == "dynamic-slice" and not as_fusion:
                e, r = self._eff(op.out_shape)
                c.hbm_bytes += 2 * e
                c.hbm_bytes_raw += 2 * r
            elif k == "dynamic-update-slice" and not as_fusion:
                names = _operand_names(op)
                upd = comp.defs.get(names[1]) if len(names) >= 2 else None
                sh = upd.out_shape if upd is not None else op.out_shape
                e, r = self._eff(sh)
                c.hbm_bytes += 2 * e
                c.hbm_bytes_raw += 2 * r
            elif k in ("parameter", "constant", "get-tuple-element", "tuple",
                       "bitcast", "after-all", "partition-id", "replica-id",
                       "copy-start", "copy-done") or k.endswith("-done"):
                pass
            else:
                add_io(op)
        self.memo[key] = c
        return c


def analyze(hlo_text: str, panel_dims=()) -> Costs:
    """Per-device roofline terms for one compiled executable."""
    comps = parse_hlo(hlo_text)
    entry = None
    for line in hlo_text.splitlines():
        m = re.match(r"^ENTRY\s+%?([\w.\-]+)", line.strip())
        if m:
            entry = m.group(1)
            break
    if entry is None or entry not in comps:
        entry = max(comps, key=lambda c: len(comps[c].ops))
    return Analyzer(comps, panel_dims).comp_costs(comps[entry],
                                                  as_fusion=False)


def roofline(costs: Costs, *, peak_flops: float, hbm_bw: float,
             ici_bw: float, ici_links: int = 4) -> Dict[str, float]:
    """Three roofline terms (seconds, per device) + dominant bottleneck."""
    t_compute = costs.flops / peak_flops
    t_memory = costs.hbm_bytes / hbm_bw
    t_coll = costs.coll_bytes / (ici_bw * ici_links)
    dom = max((("compute", t_compute), ("memory", t_memory),
               ("collective", t_coll)), key=lambda kv: kv[1])[0]
    t_bound = max(t_compute, t_memory, t_coll)
    return {"t_compute": t_compute, "t_memory": t_memory,
            "t_collective": t_coll, "bottleneck": dom,
            "t_bound": t_bound,
            "flops": costs.flops, "hbm_bytes": costs.hbm_bytes,
            "hbm_bytes_raw": costs.hbm_bytes_raw,
            "coll_bytes": costs.coll_bytes}
