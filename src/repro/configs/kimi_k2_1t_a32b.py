"""kimi-k2-1t-a32b [moe] — trillion-param MoE, 384 experts top-8."""
from .base import ArchConfig, MoEConfig, register

CONFIG = register(ArchConfig(
    arch_id="kimi-k2-1t-a32b", family="moe",
    n_layers=61, d_model=7168, n_heads=64, n_kv_heads=8, d_ff=2048,
    vocab=163840, head_dim=128, rope_theta=1_000_000.0,
    moe=MoEConfig(n_experts=384, top_k=8, d_ff_expert=2048),
    opt_moments="int8",
    notes="~1T total / ~32B active.  Expert weights are EP-sharded over "
          "'model' (384/16=24 experts per shard); optimizer moments int8 "
          "(8-bit Adam) — fp32 moments for 1T params cannot fit one pod.",
))
