"""chameleon-34b [vlm] — early-fusion, VQ image tokens; arXiv:2405.09818."""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    arch_id="chameleon-34b", family="vlm",
    n_layers=48, d_model=8192, n_heads=64, n_kv_heads=8, d_ff=22016,
    vocab=65536, head_dim=128, qk_norm=True, rope_theta=10_000.0,
    notes="early-fusion VLM backbone = dense decoder-only LM; VQ image "
          "tokens are ordinary vocab ids (frontend stub: input_specs() "
          "yields fused token streams).  Chameleon uses qk-norm for "
          "training stability (per the paper).",
))
