"""The paper's own system configuration (FUSEE testbed, §6.1).

Scaled-unit mapping used by the event-level simulator and its network cost
model (benchmarks/netmodel): the paper's testbed is 22 machines (5 MNs +
17 CNs), 56 Gbps ConnectX-3, ~2 us RTT.  The simulator executes *verbs* and
counts RTTs/bytes; the cost model turns those counts into seconds with these
constants so benchmark figures are comparable to the paper's.
"""
from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class FuseePaperConfig:
    # cluster (§6.1)
    num_mns: int = 5
    num_cns: int = 17
    clients_per_cn: int = 8
    # network model
    rtt_us: float = 2.0                 # one-sided verb round trip
    rpc_rtt_us: float = 6.0             # client<->master / ALLOC RPC
    link_gbps: float = 56.0             # per-RNIC bandwidth (IB FDR)
    mn_alloc_ops_per_s: float = 600_000.0   # weak MN cores: ALLOC handling cap
    # Clover metadata-server per-core capacity: an E5-2450 core handling an
    # index-update RPC (hash probe + allocation bookkeeping + reply).  250k
    # ops/s/core reproduces Fig. 2's 6-core saturation point.
    mdserver_ops_per_core_s: float = 250_000.0
    # KV workload defaults (§6.3)
    kv_size_bytes: int = 1024
    ycsb_keys: int = 100_000
    zipf_theta: float = 0.99
    # recovery (Table 1)
    reconnect_ms: float = 163.1
    # replication
    replication: int = 2
    index_replicas: int = 1             # comparison setting of §6.2/6.3
