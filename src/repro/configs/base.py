"""Architecture + shape configuration system.

Every assigned architecture is a frozen ``ArchConfig``; every input shape is a
``ShapeSpec``.  A (arch, shape) pair is a dry-run *cell*; ``cells()``
enumerates the 40 assigned cells with their applicability rules:

* ``long_500k`` lowers only for sub-quadratic archs (ssm / hybrid);
  pure full-attention archs skip it (DESIGN.md §Arch-applicability).
* ``decode_*`` / ``long_*`` lower ``serve_step`` (one new token against a
  KV cache / recurrent state of ``seq_len``), not ``train_step``.
* ``[audio]`` / ``[vlm]`` backbones take stub frontends: ``input_specs()``
  provides precomputed frame/patch embeddings (whisper) or fused token ids
  (chameleon — VQ image tokens are ordinary vocabulary entries).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import List, Optional, Tuple


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # 'train' | 'prefill' | 'decode'


TRAIN_4K = ShapeSpec("train_4k", 4_096, 256, "train")
PREFILL_32K = ShapeSpec("prefill_32k", 32_768, 32, "prefill")
DECODE_32K = ShapeSpec("decode_32k", 32_768, 128, "decode")
LONG_500K = ShapeSpec("long_500k", 524_288, 1, "decode")

ALL_SHAPES = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    capacity_factor: float = 1.25
    dense_residual_d_ff: int = 0   # arctic: parallel always-on dense FFN
    moe_every: int = 1             # jamba: MoE FFN on every k-th layer


@dataclass(frozen=True)
class SSMConfig:
    # mamba (S6)
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    # xlstm
    slstm_every: int = 0           # >0: every k-th layer is sLSTM (rest mLSTM)
    chunk: int = 256               # chunkwise-parallel scan block


@dataclass(frozen=True)
class ArchConfig:
    arch_id: str
    family: str                    # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0              # 0 -> d_model // n_heads
    qk_norm: bool = False
    rope_theta: float = 500_000.0
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    attn_every: int = 1            # hybrid: layer i is attention iff
                                   # (i % attn_every) == attn_phase, else mamba
    attn_phase: int = 0
    enc_dec: bool = False          # whisper: encoder-decoder
    n_enc_layers: int = 0
    enc_seq: int = 1500            # whisper frame count after conv stub
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    # distribution / memory knobs (overridable per run)
    remat: str = "block"           # 'none' | 'block' (remat each scanned layer)
    opt_moments: str = "fp32"      # 'fp32' | 'int8' (8-bit Adam for >100B)
    attn_chunk_q: int = 1024       # online-softmax query block (train/prefill)
    attn_chunk_kv: int = 2048      # kv block for decode length-sharding
    scan_layers: bool = True
    sub_quadratic: bool = False    # may lower long_500k
    notes: str = ""

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def is_moe(self) -> bool:
        return self.moe is not None

    def n_params(self) -> int:
        """Total parameter count (used for MODEL_FLOPS = 6*N*D)."""
        return _count_params(self)

    def n_active_params(self) -> int:
        """Active-per-token parameters (MoE: only top_k experts)."""
        return _count_params(self, active_only=True)

    def shapes(self) -> List[ShapeSpec]:
        out = [TRAIN_4K, PREFILL_32K]
        if not (self.enc_dec and False):  # enc-dec still decodes (whisper)
            out.append(DECODE_32K)
        if self.sub_quadratic:
            out.append(LONG_500K)
        return out

    def skipped_shapes(self) -> List[Tuple[ShapeSpec, str]]:
        out = []
        if not self.sub_quadratic:
            out.append((LONG_500K, "full attention is quadratic at 524288; "
                        "shape reserved for ssm/hybrid archs"))
        return out


def _attn_params(cfg: ArchConfig) -> int:
    hd = cfg.hd
    q = cfg.d_model * cfg.n_heads * hd
    kv = 2 * cfg.d_model * cfg.n_kv_heads * hd
    o = cfg.n_heads * hd * cfg.d_model
    qknorm = 2 * hd if cfg.qk_norm else 0
    return q + kv + o + qknorm


def _ffn_params(d_model: int, d_ff: int) -> int:
    return 3 * d_model * d_ff  # gate, up, down (SwiGLU)


def _mamba_params(cfg: ArchConfig) -> int:
    s = cfg.ssm or SSMConfig()
    d_in = s.expand * cfg.d_model
    return (cfg.d_model * 2 * d_in          # in_proj (x, z)
            + d_in * s.d_conv               # conv
            + d_in * (s.d_state * 2 + 1)    # B, C, dt per-channel proj basis
            + d_in * s.d_state              # A
            + d_in                          # D
            + d_in * cfg.d_model)           # out_proj


def _xlstm_params(cfg: ArchConfig, layer: int) -> int:
    s = cfg.ssm or SSMConfig()
    d = cfg.d_model
    slstm = s.slstm_every and ((layer + 1) % s.slstm_every == 0)
    if slstm:
        # 4 gates (i,f,z,o) input + recurrent, + up/down proj (factor 4/3)
        dp = int(4 * d / 3)
        return 4 * d * d + 4 * d * d + 2 * d * dp
    # mLSTM: qkv + i,f gates + out, inner dim 2*d
    di = 2 * d
    return d * 3 * di + 2 * d + di * d + 2 * d * di  # qkv, gates, out, up/down


def _layer_params(cfg: ArchConfig, i: int, active_only: bool) -> int:
    d = cfg.d_model
    norms = 2 * d
    if cfg.family == "ssm":
        return _xlstm_params(cfg, i) + norms
    is_attn = (i % cfg.attn_every) == cfg.attn_phase if cfg.attn_every > 1 else True
    mix = _attn_params(cfg) if is_attn else _mamba_params(cfg)
    if cfg.moe is not None and (i % cfg.moe.moe_every) == (cfg.moe.moe_every - 1):
        m = cfg.moe
        n_e = m.top_k if active_only else m.n_experts
        ffn = n_e * _ffn_params(d, m.d_ff_expert) + d * m.n_experts  # + router
        ffn += _ffn_params(d, m.dense_residual_d_ff) if m.dense_residual_d_ff else 0
    elif cfg.d_ff > 0:
        ffn = _ffn_params(d, cfg.d_ff)
    else:
        ffn = 0
    return mix + ffn + norms


def _count_params(cfg: ArchConfig, active_only: bool = False) -> int:
    total = cfg.vocab * cfg.d_model  # embed
    if not cfg.tie_embeddings:
        total += cfg.vocab * cfg.d_model  # lm head
    total += cfg.d_model  # final norm
    for i in range(cfg.n_layers):
        total += _layer_params(cfg, i, active_only)
    if cfg.enc_dec:
        for i in range(cfg.n_enc_layers):
            total += _layer_params(cfg, i, active_only)
            total += _attn_params(cfg) + cfg.d_model  # decoder cross-attn+norm
    return total


# ---------------------------------------------------------------------------
_REGISTRY: dict = {}


def register(cfg: ArchConfig) -> ArchConfig:
    _REGISTRY[cfg.arch_id] = cfg
    return cfg


def get(arch_id: str) -> ArchConfig:
    if not _REGISTRY:
        _load_all()
    return _REGISTRY[arch_id]


def all_archs() -> List[str]:
    if not _REGISTRY:
        _load_all()
    return sorted(_REGISTRY)


def _load_all():
    from . import (arctic_480b, chameleon_34b, jamba_1_5_large_398b,  # noqa
                   kimi_k2_1t_a32b, llama3_8b, mistral_large_123b,
                   qwen3_32b, smollm_360m, whisper_medium, xlstm_350m)


def cells() -> List[Tuple[ArchConfig, ShapeSpec]]:
    """All assigned (arch x shape) dry-run cells (40 total)."""
    out = []
    for a in all_archs():
        cfg = get(a)
        for s in cfg.shapes():
            out.append((cfg, s))
    return out


def reduced(cfg: ArchConfig, *, n_layers: int = 2, d_model: int = 64,
            vocab: int = 128, d_ff_scale: int = 32) -> ArchConfig:
    """Tiny same-family config for CPU smoke tests."""
    hd = max(8, d_model // max(1, cfg.n_heads // 4) // 2)
    n_heads = max(2, min(4, cfg.n_heads))
    n_kv = max(1, min(n_heads, cfg.n_kv_heads * n_heads // max(1, cfg.n_heads)))
    while n_heads % n_kv:
        n_kv -= 1
    moe = None
    if cfg.moe is not None:
        moe = dataclasses.replace(cfg.moe, n_experts=4,
                                  top_k=min(2, cfg.moe.top_k),
                                  d_ff_expert=d_ff_scale,
                                  dense_residual_d_ff=(d_ff_scale if
                                  cfg.moe.dense_residual_d_ff else 0))
    ssm = cfg.ssm
    if ssm is not None:
        ssm = dataclasses.replace(ssm, d_state=8, chunk=16)
    # keep at least one full superblock period
    period = max(cfg.attn_every,
                 (cfg.ssm.slstm_every if cfg.ssm else 0) or 1, 1)
    n_layers = max(n_layers, period)
    n_layers = ((n_layers + period - 1) // period) * period
    return dataclasses.replace(
        cfg, arch_id=cfg.arch_id + "-reduced", n_layers=n_layers,
        d_model=d_model, n_heads=n_heads, n_kv_heads=n_kv,
        d_ff=(d_ff_scale * 2 if cfg.d_ff else 0), vocab=vocab, head_dim=16,
        moe=moe, ssm=ssm, n_enc_layers=(n_layers if cfg.enc_dec else 0),
        enc_seq=24, dtype="float32", attn_chunk_q=16, attn_chunk_kv=32,
        opt_moments="fp32")
