"""jamba-1.5-large-398b [hybrid] — Mamba+attention 1:7 interleave, MoE 16e top-2."""
from .base import ArchConfig, MoEConfig, SSMConfig, register

CONFIG = register(ArchConfig(
    arch_id="jamba-1.5-large-398b", family="hybrid",
    n_layers=72, d_model=8192, n_heads=64, n_kv_heads=8, d_ff=24576,
    vocab=65536, head_dim=128, sub_quadratic=True,
    attn_every=8, attn_phase=4,  # 1 attention : 7 mamba, attn at i%8==4
    moe=MoEConfig(n_experts=16, top_k=2, d_ff_expert=24576, moe_every=2),
    ssm=SSMConfig(d_state=16, d_conv=4, expand=2, chunk=256),
    opt_moments="int8",
    notes="Jamba-1.5-Large: 72 layers, attention on every 8th layer "
          "(i%8==4), MoE FFN on every 2nd layer.  Runs long_500k: the 9 "
          "attention layers hold a 524288-token paged KV cache; the 63 "
          "mamba layers carry O(1) state.",
))
