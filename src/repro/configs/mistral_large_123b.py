"""mistral-large-123b [dense] — hf:mistralai/Mistral-Large-Instruct-2407."""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    arch_id="mistral-large-123b", family="dense",
    n_layers=88, d_model=12288, n_heads=96, n_kv_heads=8, d_ff=28672,
    vocab=32768, head_dim=128, rope_theta=1_000_000.0,
    opt_moments="int8",
    notes="123B dense; GQA kv=8; the largest dense cell in the pool.",
))
