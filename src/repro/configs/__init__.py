"""Assigned-architecture configs (``--arch <id>``) + the paper's own config."""
from .base import (ALL_SHAPES, ArchConfig, DECODE_32K, LONG_500K, MoEConfig,
                   PREFILL_32K, SSMConfig, ShapeSpec, TRAIN_4K, all_archs,
                   cells, get, reduced, register)
from .fusee_paper import FuseePaperConfig

__all__ = ["ArchConfig", "ShapeSpec", "MoEConfig", "SSMConfig", "get",
           "all_archs", "cells", "reduced", "register", "TRAIN_4K",
           "PREFILL_32K", "DECODE_32K", "LONG_500K", "ALL_SHAPES",
           "FuseePaperConfig"]
