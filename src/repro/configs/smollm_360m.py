"""smollm-360m [dense] — llama-arch small; hf:HuggingFaceTB/SmolLM-360M."""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    arch_id="smollm-360m", family="dense",
    n_layers=32, d_model=960, n_heads=15, n_kv_heads=5, d_ff=2560,
    vocab=49152, head_dim=64, rope_theta=10_000.0, tie_embeddings=True,
    notes="small llama-family model; also the end-to-end training example. "
          "15 heads is not divisible by tp=16: attention heads replicate "
          "over 'model' while FFN/vocab still shard (see models/common.py).",
))
