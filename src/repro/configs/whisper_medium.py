"""whisper-medium [audio] — enc-dec; conv frontend stubbed; arXiv:2212.04356."""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    arch_id="whisper-medium", family="audio",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=16, d_ff=4096,
    vocab=51865, head_dim=64, enc_dec=True, n_enc_layers=24, enc_seq=1500,
    rope_theta=10_000.0,
    notes="transformer BACKBONE only: input_specs() provides precomputed "
          "frame embeddings (batch, 1500, d_model) in place of the conv "
          "frontend (stub per assignment).  Decoder self-attn KV cache + "
          "per-request cross-attn KV (computed once at encode).",
))
