"""arctic-480b [moe] — 128 experts top-2 + dense residual FFN."""
from .base import ArchConfig, MoEConfig, register

CONFIG = register(ArchConfig(
    arch_id="arctic-480b", family="moe",
    n_layers=35, d_model=7168, n_heads=56, n_kv_heads=8, d_ff=4864,
    vocab=32000, head_dim=128, rope_theta=1_000_000.0,
    moe=MoEConfig(n_experts=128, top_k=2, d_ff_expert=4864,
                  dense_residual_d_ff=4864),
    opt_moments="int8",
    notes="dense-MoE hybrid: a parallel always-on dense FFN (d_ff=4864) "
          "residual alongside the 128e top-2 MoE branch (Snowflake Arctic).",
))
