"""qwen3-32b [dense] — qk_norm + GQA; hf:Qwen/Qwen3-32B family."""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    arch_id="qwen3-32b", family="dense",
    n_layers=64, d_model=5120, n_heads=64, n_kv_heads=8, d_ff=25600,
    vocab=151936, head_dim=128, qk_norm=True, rope_theta=1_000_000.0,
    notes="qk RMS-norm per head (qwen3); head_dim=128 so q-proj is "
          "n_heads*head_dim=8192 != d_model (as in the real model).",
))
