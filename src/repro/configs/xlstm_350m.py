"""xlstm-350m [ssm] — sLSTM + mLSTM blocks; arXiv:2405.04517."""
from .base import ArchConfig, SSMConfig, register

CONFIG = register(ArchConfig(
    arch_id="xlstm-350m", family="ssm",
    n_layers=24, d_model=1024, n_heads=4, n_kv_heads=4, d_ff=0,
    vocab=50304, head_dim=0, sub_quadratic=True,
    ssm=SSMConfig(slstm_every=4, chunk=256),
    notes="xLSTM[7:1]-style: every 4th block sLSTM (scalar memory, strictly "
          "sequential lax.scan), rest mLSTM (matrix memory, chunkwise-"
          "parallel).  No FFN (d_ff=0): blocks carry internal up/down "
          "projections.  Runs long_500k (recurrent state is O(1) in seq).",
))
