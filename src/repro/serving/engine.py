"""Continuous-batching serve engine over a FUSEE-managed KV pool.

Requests stream in; the engine packs up to ``max_batch`` of them into fixed
decode slots, prefills new arrivals, decodes the active set each step, and
retires finished sequences.  The FUSEE pool provides:

* prefix-cache metadata: prompt token-blocks are hashed; block hashes are
  SEARCHed in the RACE index (race_lookup kernel) — hits are counted as
  reusable prefix pages (the disaggregated prefix cache), misses are
  INSERTed via SNAPSHOT epochs after prefill;
* page accounting for each slot's cache blocks via the two-level allocator
  (chunk grants from pool shards -> client slab);
* crash recovery of engine workers via the embedded page log.

The engine is deliberately synchronous (one jitted decode step per tick) —
the distributed story lives in the model (pjit) and pool (replicated
metadata), not in host threading.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.api import KVStore, Op
from repro.core.events import OK
from repro.models.model import Model
from .backend import DeviceBackend
from .kvpool import PoolConfig

BLOCK_TOKENS = 64   # prefix-hash granularity


@dataclass
class Request:
    rid: int
    prompt: np.ndarray              # (S,) int32
    max_new: int = 16
    out: List[int] = field(default_factory=list)
    slot: int = -1
    pages: Optional[np.ndarray] = None
    surplus: Optional[np.ndarray] = None  # allocated pages that lost insert
    prefix_hits: int = 0


def _block_hashes(prompt: np.ndarray) -> np.ndarray:
    """Rolling content hash per BLOCK_TOKENS block (prefix identity)."""
    nb = len(prompt) // BLOCK_TOKENS
    out = np.zeros(max(nb, 0), np.int64)
    h = 1469598103  # FNV-style rolling hash in Python ints (no overflow)
    for b in range(nb):
        blk = prompt[b * BLOCK_TOKENS:(b + 1) * BLOCK_TOKENS]
        for x in (b, *(int(t) for t in blk[::7])):
            h = ((h ^ x) * 1099511628211) & 0x7FFFFFFF
        out[b] = h
    return out.astype(np.int32)


class ServeEngine:
    def __init__(self, model: Model, params, *, max_batch: int = 4,
                 max_len: int = 256, pool_cfg: Optional[PoolConfig] = None,
                 cid: int = 0, greedy: bool = True, seed: int = 0):
        self.model = model
        self.params = params
        self.max_batch = max_batch
        self.max_len = max_len
        # unified store API over the device-resident pool (one public KV
        # surface shared with the event-level core; see core/api.py)
        self._backend = DeviceBackend(pool_cfg or PoolConfig(), cid=cid,
                                      seed=seed)
        self.store = KVStore(self._backend)
        self.cid = cid
        self.greedy = greedy
        self.queue: List[Request] = []
        self.active: Dict[int, Request] = {}
        self.finished: List[Request] = []
        self.cache = None
        self.slots_free = list(range(max_batch))
        self.slot_tokens = np.zeros((max_batch, max_len), np.int32)
        self.slot_len = np.zeros((max_batch,), np.int32)
        self._decode = jax.jit(model.decode_step, donate_argnums=1)
        self.steps = 0

    @property
    def pool(self):
        """The device pool behind the store (stats / recovery / tests)."""
        return self._backend.pool

    def submit(self, req: Request):
        self.queue.append(req)

    # -------------------------------------------------- worker recovery --
    def crash_worker(self, cid: Optional[int] = None):
        """Crash-stop an engine worker: its slab state is dropped and, if
        it is THIS engine's worker, further store submits raise the typed
        ``ClientCrashed`` — the serving twin of the event-level surface."""
        cid = self.cid if cid is None else cid
        self.pool.crash_client(cid)
        if cid == self.cid:
            self._backend.crashed = True

    def recover_worker(self, cid: Optional[int] = None,
                       reassign_to: Optional[int] = None) -> Dict[str, int]:
        """§5.3 recovery from the embedded page log: re-own chunks, reclaim
        unused pages, redo uncommitted winner index writes.  Recovering
        this engine's own worker (or reassigning onto it) reopens its
        store for submits."""
        cid = self.cid if cid is None else cid
        st = self.pool.recover_client(cid, reassign_to=reassign_to)
        new_owner = reassign_to if reassign_to is not None else cid
        if new_owner == self.cid:
            self._backend.crashed = False
        return st

    # ------------------------------------------------ pool elasticity --
    def scale_pool(self) -> int:
        """Elastic scale-out of the disaggregated prefix-cache pool (the
        serving twin of ``FuseeCluster.add_mn``): a fresh grant shard — a
        "memory node" of the two-level allocator — joins the ring, and
        ungranted page chunks re-home onto it.  Granted chunks (live
        prefix pages) stay put, so the engine keeps serving throughout.
        Returns the new shard id."""
        return self.pool.add_shard()

    def health(self) -> Dict:
        """Engine observability: slot occupancy + pool/backend counters
        (the serving counterpart of ``FuseeCluster.health()``)."""
        return {
            "active": len(self.active), "queued": len(self.queue),
            "finished": len(self.finished), "slots_free": len(self.slots_free),
            "steps": self.steps, "pool_shards": self.pool.cfg.n_shards,
            **self._backend.stats(),
        }

    def metrics(self) -> Dict:
        """Registry-style metrics snapshot (the serving twin of
        ``FuseeCluster.metrics()``): the engine/backend counters under
        ``serve.*`` dotted names in the same sectioned layout, so merge /
        diff / export tooling (``repro.obs``) applies unchanged."""
        counters = {
            "serve.active": len(self.active),
            "serve.queued": len(self.queue),
            "serve.finished": len(self.finished),
            "serve.steps": self.steps,
        }
        for k, v in self._backend.stats().items():
            if isinstance(v, (int, np.integer)) and not isinstance(v, bool):
                counters["serve." + k] = int(v)
        return {"counters": counters,
                "gauges": {"serve.slots_free": len(self.slots_free),
                           "serve.pool_shards": self.pool.cfg.n_shards},
                "histograms": {}, "series": {}, "heat": {}}

    def list_prefixes(self, start: int = 0, count: int = 64) -> List[tuple]:
        """Ordered listing of live prefix-cache entries: the next
        ``count`` block-hash keys >= ``start`` in key order, each with its
        backing page id — the serving twin of the simulator's SCAN
        (located via the shared ``leaf_probe`` entry point, validated
        against the device index in one batched ``race_lookup`` probe)."""
        res = self.store.submit(Op.scan(start, count)).result()
        keys = [k for (k, _v) in (res.value or [])]
        if not keys:
            return []
        ptr, found = self.pool.search(np.array(keys, np.int64)
                                      .astype(np.int32))
        return [(int(k), int(p)) for k, p, f in
                zip(keys, ptr, found) if f]

    # ------------------------------------------------------------- ticks --
    def _admit(self):
        """Admit every queued request a free slot allows, then serve ALL
        their prefix lookups with one batched GET wave (a single
        pool.search -> race_lookup invocation per admit tick, not one per
        request) and all their misses with one batched INSERT wave — the
        serving twin of the simulator's fleet tick (core/fleet.py)."""
        admitted: List[Request] = []
        while self.queue and self.slots_free:
            req = self.queue.pop(0)
            req.slot = self.slots_free.pop(0)
            admitted.append(req)
        if not admitted:
            return False
        hashes = [_block_hashes(req.prompt) for req in admitted]
        flat = [int(h) for hs in hashes for h in hs]
        if flat:
            res = [f.result() for f in self.store.submit_batch(
                [Op.get(h) for h in flat])]
            found = np.array([r.status == OK for r in res], bool)
            miss_idx = [i for i in range(len(flat)) if not found[i]]
            ins_res = {}
            if miss_idx:
                # duplicate hashes across requests collapse to one page in
                # the device batch (concurrent upserts of one key)
                ins = [f.result() for f in self.store.submit_batch(
                    [Op.insert(flat[i], None) for i in miss_idx])]
                ins_res = dict(zip(miss_idx, ins))
            pos = 0
            for req, hs in zip(admitted, hashes):
                fnd = found[pos:pos + len(hs)]
                req.prefix_hits = int(fnd.sum())
                rs = [ins_res[pos + j] for j in range(len(hs)) if not fnd[j]]
                if rs:
                    req.pages = np.array(
                        [r.page if r.page is not None else -1 for r in rs],
                        np.int32)
                    # a page whose insert lost (another worker's page won
                    # the slot) is unreferenced by the index: remember it
                    # for release at retire
                    req.surplus = np.array(
                        [r.page for r in rs
                         if r.status != OK and r.page is not None
                         and r.page >= 0], np.int32)
                pos += len(hs)
        for req in admitted:
            self.slot_tokens[req.slot, :len(req.prompt)] = req.prompt
            self.slot_len[req.slot] = len(req.prompt)
            self.active[req.slot] = req
        return True

    def _prefill_all(self):
        """(Re)prefill the whole active batch into a fresh cache.

        Fixed-slot batching: the batch tensor always has max_batch rows;
        empty slots hold a pad prompt of length 1."""
        L = int(self.slot_len.max()) if self.active else 1
        L = max(L, 1)
        toks = jnp.asarray(self.slot_tokens[:, :L])
        logits, cache = self.model.prefill(self.params, toks,
                                           max_len=self.max_len)
        self.cache = cache
        return logits

    def step(self) -> int:
        """One engine tick: admit + (re)prefill if membership changed, else
        decode one token for every active slot.  Returns #active."""
        changed = self._admit()
        if not self.active:
            return 0
        if changed or self.cache is None:
            logits = self._prefill_all()
            nxt = np.asarray(jnp.argmax(logits[:, -1], axis=-1))
            for s, req in self.active.items():
                tok = int(nxt[s])
                req.out.append(tok)
                pos = int(self.slot_len[s])
                self.slot_tokens[s, pos] = tok
                self.slot_len[s] = pos + 1
        else:
            token = jnp.asarray(
                self.slot_tokens[np.arange(self.max_batch),
                                 np.maximum(self.slot_len - 1, 0)][:, None])
            logits, self.cache = self._decode(self.params, self.cache, token)
            nxt = np.asarray(jnp.argmax(logits[:, -1], axis=-1))
            for s, req in self.active.items():
                tok = int(nxt[s])
                req.out.append(tok)
                pos = int(self.slot_len[s])
                if pos < self.max_len:
                    self.slot_tokens[s, pos] = tok
                    self.slot_len[s] = pos + 1
        self.steps += 1
        # retire finished
        for s in list(self.active):
            req = self.active[s]
            if len(req.out) >= req.max_new or self.slot_len[s] >= self.max_len:
                self.finished.append(req)
                del self.active[s]
                self.slots_free.append(s)
                self.slot_tokens[s] = 0
                self.slot_len[s] = 0
                if req.surplus is not None and len(req.surplus):
                    # prefix pages referenced by the index stay in the store
                    # (the shared cache); pages this request allocated that
                    # LOST their insert race are unreachable — free them
                    # back to the pool.
                    self._backend.release_pages(req.surplus)
        return len(self.active)

    def run(self, max_ticks: int = 1000) -> List[Request]:
        while (self.queue or self.active) and self.steps < max_ticks:
            self.step()
        return self.finished
