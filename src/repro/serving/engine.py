"""Continuous-batching serve engine over a FUSEE-managed KV pool.

Requests stream in; the engine packs up to ``max_batch`` of them into fixed
decode slots, prefills new arrivals, decodes the active set each step, and
retires finished sequences.  The FUSEE pool provides:

* prefix-cache metadata: prompt token-blocks are hashed; block hashes are
  SEARCHed in the RACE index (race_lookup kernel) — hits are counted as
  reusable prefix pages (the disaggregated prefix cache), misses are
  INSERTed via SNAPSHOT epochs after prefill;
* page accounting for each slot's cache blocks via the two-level allocator
  (chunk grants from pool shards -> client slab);
* crash recovery of engine workers via the embedded page log.

The engine is deliberately synchronous (one jitted decode step per tick) —
the distributed story lives in the model (pjit) and pool (replicated
metadata), not in host threading.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.model import Model
from .kvpool import KVPool, PoolConfig

BLOCK_TOKENS = 64   # prefix-hash granularity


@dataclass
class Request:
    rid: int
    prompt: np.ndarray              # (S,) int32
    max_new: int = 16
    out: List[int] = field(default_factory=list)
    slot: int = -1
    pages: Optional[np.ndarray] = None
    prefix_hits: int = 0


def _block_hashes(prompt: np.ndarray) -> np.ndarray:
    """Rolling content hash per BLOCK_TOKENS block (prefix identity)."""
    nb = len(prompt) // BLOCK_TOKENS
    out = np.zeros(max(nb, 0), np.int64)
    h = 1469598103  # FNV-style rolling hash in Python ints (no overflow)
    for b in range(nb):
        blk = prompt[b * BLOCK_TOKENS:(b + 1) * BLOCK_TOKENS]
        for x in (b, *(int(t) for t in blk[::7])):
            h = ((h ^ x) * 1099511628211) & 0x7FFFFFFF
        out[b] = h
    return out.astype(np.int32)


class ServeEngine:
    def __init__(self, model: Model, params, *, max_batch: int = 4,
                 max_len: int = 256, pool_cfg: Optional[PoolConfig] = None,
                 cid: int = 0, greedy: bool = True, seed: int = 0):
        self.model = model
        self.params = params
        self.max_batch = max_batch
        self.max_len = max_len
        self.pool = KVPool(pool_cfg or PoolConfig())
        self.cid = cid
        self.greedy = greedy
        self.queue: List[Request] = []
        self.active: Dict[int, Request] = {}
        self.finished: List[Request] = []
        self.cache = None
        self.slots_free = list(range(max_batch))
        self.slot_tokens = np.zeros((max_batch, max_len), np.int32)
        self.slot_len = np.zeros((max_batch,), np.int32)
        self._decode = jax.jit(model.decode_step, donate_argnums=1)
        self.steps = 0

    def submit(self, req: Request):
        self.queue.append(req)

    # ------------------------------------------------------------- ticks --
    def _admit(self):
        admitted = False
        while self.queue and self.slots_free:
            req = self.queue.pop(0)
            req.slot = self.slots_free.pop(0)
            # FUSEE prefix lookup: count reusable pages for this prompt
            hashes = _block_hashes(req.prompt)
            if len(hashes):
                ptr, found = self.pool.search(hashes)
                req.prefix_hits = int(found.sum())
                missing = hashes[~found]
                if len(missing):
                    pages = self.pool.alloc_pages(self.cid, len(missing))
                    live = pages >= 0
                    if live.any():
                        self.pool.write_pages(self.cid, pages[live],
                                              missing[live], opcode=1)
                        self.pool.insert_batch(self.cid, missing[live],
                                               pages[live])
                    req.pages = pages
            self.slot_tokens[req.slot, :len(req.prompt)] = req.prompt
            self.slot_len[req.slot] = len(req.prompt)
            self.active[req.slot] = req
            admitted = True
        return admitted

    def _prefill_all(self):
        """(Re)prefill the whole active batch into a fresh cache.

        Fixed-slot batching: the batch tensor always has max_batch rows;
        empty slots hold a pad prompt of length 1."""
        L = int(self.slot_len.max()) if self.active else 1
        L = max(L, 1)
        toks = jnp.asarray(self.slot_tokens[:, :L])
        logits, cache = self.model.prefill(self.params, toks,
                                           max_len=self.max_len)
        self.cache = cache
        return logits

    def step(self) -> int:
        """One engine tick: admit + (re)prefill if membership changed, else
        decode one token for every active slot.  Returns #active."""
        changed = self._admit()
        if not self.active:
            return 0
        if changed or self.cache is None:
            logits = self._prefill_all()
            nxt = np.asarray(jnp.argmax(logits[:, -1], axis=-1))
            for s, req in self.active.items():
                tok = int(nxt[s])
                req.out.append(tok)
                pos = int(self.slot_len[s])
                self.slot_tokens[s, pos] = tok
                self.slot_len[s] = pos + 1
        else:
            token = jnp.asarray(
                self.slot_tokens[np.arange(self.max_batch),
                                 np.maximum(self.slot_len - 1, 0)][:, None])
            logits, self.cache = self._decode(self.params, self.cache, token)
            nxt = np.asarray(jnp.argmax(logits[:, -1], axis=-1))
            for s, req in self.active.items():
                tok = int(nxt[s])
                req.out.append(tok)
                pos = int(self.slot_len[s])
                if pos < self.max_len:
                    self.slot_tokens[s, pos] = tok
                    self.slot_len[s] = pos + 1
        self.steps += 1
        # retire finished
        for s in list(self.active):
            req = self.active[s]
            if len(req.out) >= req.max_new or self.slot_len[s] >= self.max_len:
                self.finished.append(req)
                del self.active[s]
                self.slots_free.append(s)
                self.slot_tokens[s] = 0
                self.slot_len[s] = 0
                if req.pages is not None:
                    live = req.pages[req.pages >= 0]
                    # prefix pages stay in the store (cache); only surplus
                    # pages would be freed here in an eviction policy.
        return len(self.active)

    def run(self, max_ticks: int = 1000) -> List[Request]:
        while (self.queue or self.active) and self.steps < max_ticks:
            self.step()
        return self.finished
