"""The FUSEE-managed disaggregated KV-cache pool (INTERNAL substrate).

This module is not a public KV surface: clients go through the unified
``core.api.KVStore`` over ``serving.backend.DeviceBackend``, which lowers
Op batches onto the pool below.  Whitebox tests import it directly.

This is the paper's technique as a first-class serving feature: the
*metadata* of a paged KV-cache prefix store — the RACE hash index mapping
``prefix_hash -> page``, and the memory-management information — lives in
replicated device arrays and is manipulated by serving workers (clients)
with CAS epochs, not by a metadata server.

Components, mapped 1:1 to the paper:

* RACE index (§4.2): ``(r, n_buckets, slots_per_bucket)`` int32 replicas;
  SEARCH = batched probe (the race_lookup Pallas kernel on replica 0 = the
  primary); INSERT/UPDATE/DELETE = SNAPSHOT epochs (snapshot_jax.py).
* Two-level memory management (§4.4): "memory nodes" (pool shards) grant
  coarse chunks of ``chunk_pages`` pages from a per-shard grant table
  (compute-light: a cursor bump, recorded per client); clients carve single
  pages out of their chunks with local free lists (slab).  Frees set bits
  in a per-chunk free bitmap (FAA analog); owners reclaim in batches.
* Embedded operation log (§4.5): every page carries a log record
  (old slot value, opcode, key, used/invalid bits) written together with
  the page payload; per-client allocation order forms the recovery chain
  (next/prev pointers pre-positioned from the deterministic free list).
* Recovery (§5.3): ``recover_client`` re-owns a crashed client's chunks
  from the grant table, walks its allocation-order log chain, reclaims
  incomplete pages, and redoes/commits in-flight index updates.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import race_lookup
from . import slots_jax as SL
from .snapshot_jax import snapshot_epoch

OP_INSERT, OP_UPDATE, OP_DELETE = 1, 2, 3


@dataclass
class PoolConfig:
    n_pages: int = 4096          # pool pages per shard group
    n_buckets: int = 1024        # RACE combined buckets
    slots_per_bucket: int = 8
    replicas: int = 3            # index replication factor r
    chunk_pages: int = 64        # coarse grant unit (the "16MB block")
    n_shards: int = 4            # "memory nodes" granting chunks


@dataclass
class ClientSlab:
    """Client-side fine-grained allocator (one uniform size class)."""
    free: List[int] = field(default_factory=list)   # FIFO page free list
    chunks: List[int] = field(default_factory=list)
    last_alloc: int = 0


class KVPool:
    """Host-coordinated, device-resident FUSEE pool.

    Device state (jnp): index replicas, page log, free bitmap.
    Host state (np): grant table cursor per shard, per-client slabs —
    exactly the split the paper prescribes (coarse state at MNs, fine state
    at clients)."""

    def __init__(self, cfg: PoolConfig, seed: int = 0):
        self.cfg = cfg
        M = cfg.n_buckets * cfg.slots_per_bucket
        self.index = jnp.zeros((cfg.replicas, M), jnp.int32)
        # page log: [old_value, opcode, key, flags(used|invalid<<1)]
        self.log = jnp.zeros((cfg.n_pages, 4), jnp.int32)
        # next/prev allocation-order chain per page (+1; 0 = nil)
        self.chain = jnp.zeros((cfg.n_pages, 2), jnp.int32)
        self.free_bitmap = jnp.zeros((cfg.n_pages,), jnp.int8)
        # coarse level: grant table (page-chunk -> client+1), shard cursors
        n_chunks = cfg.n_pages // cfg.chunk_pages
        self.grant = np.zeros((n_chunks,), np.int32)
        self.shard_of_chunk = np.arange(n_chunks) % cfg.n_shards
        self.cursor = np.zeros((cfg.n_shards,), np.int32)
        self.slabs: Dict[int, ClientSlab] = {}
        self.key = jax.random.PRNGKey(seed)
        self.stats = {"alloc_rpcs": 0, "epochs": 0, "search_batches": 0}

    # ------------------------------------------------ two-level allocation --
    def _grant_chunk(self, cid: int) -> Optional[int]:
        """MN-side ALLOC (compute-light): grab the next free chunk on the
        client's home shard (round-robin over shards on exhaustion)."""
        cfg = self.cfg
        for probe in range(cfg.n_shards):
            sh = (cid + probe) % cfg.n_shards
            mine = np.where((self.shard_of_chunk == sh) & (self.grant == 0))[0]
            if len(mine):
                c = int(mine[0])
                self.grant[c] = cid + 1
                self.stats["alloc_rpcs"] += 1
                return c
        return None

    def add_shard(self) -> int:
        """Elastic grant-shard scale-out (the serving twin of
        ``FuseeCluster.add_mn``): a new "memory node" joins the grant
        ring; every still-ungranted chunk re-homes onto the grown ring
        round-robin.  Granted chunks (live pages) never move — at grant
        granularity the dual-write/copy window of the event-level
        migration engine is unnecessary, because chunk ownership, not
        page bytes, is the only sharded state here."""
        cfg = self.cfg
        self.cfg = cfg = dataclasses.replace(cfg, n_shards=cfg.n_shards + 1)
        self.cursor = np.concatenate([self.cursor, np.zeros(1, np.int32)])
        free = self.grant == 0
        self.shard_of_chunk[free] = \
            np.arange(int(free.sum())) % cfg.n_shards
        return cfg.n_shards - 1

    def _slab(self, cid: int) -> ClientSlab:
        return self.slabs.setdefault(cid, ClientSlab())

    def alloc_pages(self, cid: int, n: int) -> np.ndarray:
        """Client-side fine allocation of n pages (slab pop; grants chunks
        as needed).  Returns page ids (-1 = pool exhausted)."""
        sl = self._slab(cid)
        out = []
        for _ in range(n):
            if not sl.free:
                c = self._grant_chunk(cid)
                if c is None:
                    out.append(-1)
                    continue
                base = c * self.cfg.chunk_pages
                sl.free.extend(range(base, base + self.cfg.chunk_pages))
                sl.chunks.append(c)
            out.append(sl.free.pop(0))
        return np.array(out, np.int32)

    def write_pages(self, cid: int, pages: np.ndarray, keys: np.ndarray,
                    opcode: int):
        """Write page payload + embedded log entry in ONE device op (the
        paper's single-RDMA_WRITE log embedding).  Chain pointers come from
        the deterministic slab order (pre-positioned)."""
        sl = self._slab(cid)
        nxt = np.array([sl.free[0] + 1 if sl.free else 0] * len(pages),
                       np.int32)
        for i in range(len(pages) - 1):
            nxt[i] = pages[i + 1] + 1
        prv = np.concatenate([[sl.last_alloc], pages[:-1] + 1]).astype(np.int32)
        if len(pages):
            sl.last_alloc = int(pages[-1]) + 1
        pg = jnp.asarray(pages)
        entries = jnp.stack([jnp.zeros(len(pages), jnp.int32),
                             jnp.full((len(pages),), opcode, jnp.int32),
                             jnp.asarray(keys, jnp.int32),
                             jnp.ones(len(pages), jnp.int32)], axis=1)
        self.log = self.log.at[pg].set(entries)
        self.chain = self.chain.at[pg].set(
            jnp.stack([jnp.asarray(nxt), jnp.asarray(prv)], axis=1))

    def free_pages(self, pages: np.ndarray):
        """Any client: set free bits (the RDMA_FAA on the free bitmap)."""
        self.free_bitmap = self.free_bitmap.at[jnp.asarray(pages)].set(1)

    def reclaim(self, cid: int) -> int:
        """Owner-side batched reclaim of freed pages in own chunks (§4.4)."""
        sl = self._slab(cid)
        bm = np.asarray(self.free_bitmap)
        n = 0
        for c in sl.chunks:
            base = c * self.cfg.chunk_pages
            for p in range(base, base + self.cfg.chunk_pages):
                if bm[p]:
                    sl.free.append(p)
                    n += 1
        if n:
            idx = jnp.asarray([p for c in sl.chunks
                               for p in range(c * self.cfg.chunk_pages,
                                              (c + 1) * self.cfg.chunk_pages)])
            self.free_bitmap = self.free_bitmap.at[idx].set(0)
            self.log = self.log.at[idx, 3].set(0)
        return n

    # -------------------------------------------------------------- index --
    def search(self, keys: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
        """Batched SEARCH on the primary replica (race_lookup kernel), with
        the RACE data-access integrity check: the key stored on the pointed
        page must match, or the probe is a fingerprint collision -> miss."""
        cfg = self.cfg
        self.stats["search_batches"] += 1
        idx2d = self.index[0].reshape(cfg.n_buckets, cfg.slots_per_bucket)
        n = len(keys)
        pad = -(-n // 256) * 256 - n
        kp = jnp.asarray(np.concatenate([keys, np.zeros(pad, np.int32)]))
        ptr, found = race_lookup(kp, idx2d)
        ptr, found = np.asarray(ptr[:n]), np.asarray(found[:n])
        page_keys = np.asarray(self.log[jnp.asarray(ptr), 2])
        verified = found & (page_keys == keys)
        shadowed = found & ~verified
        if shadowed.any():
            # collision path (read amplification): probe ALL candidate
            # slots of both buckets and verify keys, as RACE prescribes
            p2, f2 = self._search_all_candidates(keys[shadowed])
            ptr = ptr.copy()
            ptr[shadowed] = p2
            verified = verified.copy()
            verified[shadowed] = f2
        return np.where(verified, ptr, 0), verified

    def _search_all_candidates(self, keys: np.ndarray):
        cfg = self.cfg
        spb = cfg.slots_per_bucket
        kj = jnp.asarray(keys, jnp.int32)
        b1, b2, fp = self._slot_candidates(kj)
        idx0 = self.index[0].reshape(cfg.n_buckets, spb)
        rows = np.asarray(jnp.concatenate([idx0[b1], idx0[b2]], axis=1))
        fpv = np.asarray(fp)
        log_keys = np.asarray(self.log[:, 2])
        ptr = np.zeros(len(keys), np.int32)
        found = np.zeros(len(keys), bool)
        for i in range(len(keys)):
            for w in rows[i]:
                if w == 0:
                    continue
                if ((int(w) >> 24) & 0xFF) == fpv[i] and \
                        log_keys[int(w) & 0xFFFFFF] == keys[i]:
                    ptr[i] = int(w) & 0xFFFFFF
                    found[i] = True
                    break
        return ptr, found

    def _slot_candidates(self, keys: jnp.ndarray):
        cfg = self.cfg
        b1, b2 = SL.bucket_pair(keys, cfg.n_buckets)
        fp = SL.fingerprint(keys)
        return b1, b2, fp

    def insert_batch(self, cid: int, keys: np.ndarray, pages: np.ndarray,
                     opcode: int = OP_INSERT) -> np.ndarray:
        """INSERT/UPDATE a batch of prefix keys -> pages via one (or more)
        SNAPSHOT epochs.  Returns success mask."""
        cfg = self.cfg
        keys_j = jnp.asarray(keys, jnp.int32)
        pages_j = jnp.asarray(pages, jnp.int32)
        b1, b2, fp = self._slot_candidates(keys_j)
        v_new = SL.pack_slot(fp, pages_j)
        M = cfg.n_buckets * cfg.slots_per_bucket
        done = np.zeros(len(keys), bool)
        spb = cfg.slots_per_bucket
        for attempt in range(2 * spb):
            # pick a target slot per key: existing fp-match else first empty
            idx0 = self.index[0].reshape(cfg.n_buckets, spb)
            rows = jnp.concatenate([idx0[b1], idx0[b2]], axis=1)  # (W, 2spb)
            offs = jnp.concatenate(
                [b1[:, None] * spb + jnp.arange(spb)[None],
                 b2[:, None] * spb + jnp.arange(spb)[None]], axis=1)
            # fp match alone is not enough: verify the page's key so a
            # colliding entry is never overwritten (RACE integrity check)
            page_keys = self.log[SL.slot_ptr(rows), 2]
            is_match = ((SL.slot_fp(rows) == fp[:, None])
                        & (rows != 0) & (page_keys == keys_j[:, None]))
            is_empty = rows == 0
            cand = jnp.where(is_match.any(1),
                             jnp.argmax(is_match, 1),
                             jnp.argmax(is_empty, 1))
            ok = is_match.any(1) | is_empty.any(1)
            slot = jnp.take_along_axis(offs, cand[:, None], 1)[:, 0]
            v_old = jnp.take_along_axis(rows, cand[:, None], 1)[:, 0]
            act = jnp.asarray(~done) & ok
            slot_i = jnp.where(act, slot, -1)
            self.key, k = jax.random.split(self.key)
            res = snapshot_epoch(self.index, slot_i, v_old, v_new, k)
            self.index = res.index
            self.stats["epochs"] += 1
            # commit logs of winners (old value into the embedded entry)
            wpg = jnp.where(res.win, pages_j, self.cfg.n_pages)
            self.log = self.log.at[wpg, 0].set(
                v_old | jnp.int32(1 << 30), mode="drop")
            # a winner that overwrote a same-key slot superseded that key's
            # old page: free it (any-client bitmap free, §4.4) so upserts
            # don't leak pool capacity
            superseded = np.asarray(
                jnp.where(res.win & (v_old != 0), SL.slot_ptr(v_old), -1))
            self.free_pages(superseded[superseded >= 0])
            done |= np.asarray(res.win)
            if done.all():
                break
        return done

    def delete_batch(self, cid: int, keys: np.ndarray) -> np.ndarray:
        """DELETE: SNAPSHOT-write slot -> 0 (plus temp log page, elided)."""
        cfg = self.cfg
        keys_j = jnp.asarray(keys, jnp.int32)
        ptr, found = self.search(keys)
        b1, b2, fp = self._slot_candidates(keys_j)
        spb = cfg.slots_per_bucket
        idx0 = self.index[0].reshape(cfg.n_buckets, spb)
        rows = jnp.concatenate([idx0[b1], idx0[b2]], axis=1)
        offs = jnp.concatenate(
            [b1[:, None] * spb + jnp.arange(spb)[None],
             b2[:, None] * spb + jnp.arange(spb)[None]], axis=1)
        page_keys = self.log[SL.slot_ptr(rows), 2]
        is_match = ((SL.slot_fp(rows) == fp[:, None]) & (rows != 0)
                    & (page_keys == keys_j[:, None]))
        slot = jnp.take_along_axis(offs, jnp.argmax(is_match, 1)[:, None],
                                   1)[:, 0]
        v_old = jnp.take_along_axis(rows, jnp.argmax(is_match, 1)[:, None],
                                    1)[:, 0]
        act = jnp.asarray(found) & is_match.any(1)
        self.key, k = jax.random.split(self.key)
        res = snapshot_epoch(self.index, jnp.where(act, slot, -1), v_old,
                             jnp.zeros_like(v_old), k)
        self.index = res.index
        self.stats["epochs"] += 1
        # free the deleted pages (any-client free via bitmap)
        dead = np.asarray(jnp.where(res.win, SL.slot_ptr(v_old), -1))
        self.free_pages(dead[dead >= 0])
        return np.asarray(res.win)

    # ----------------------------------------------------------- recovery --
    def crash_client(self, cid: int):
        self.slabs.pop(cid, None)

    def recover_client(self, cid: int, reassign_to: Optional[int] = None
                       ) -> Dict[str, int]:
        """§5.3 for the serving pool: re-own chunks from the grant table,
        walk the embedded-log chain, reclaim unused pages, redo uncommitted
        winner index writes."""
        cfg = self.cfg
        stats = {"chunks": 0, "used_pages": 0, "reclaimed": 0, "redone": 0}
        chunks = np.where(self.grant == cid + 1)[0]
        stats["chunks"] = len(chunks)
        log = np.asarray(self.log)
        new_owner = reassign_to if reassign_to is not None else cid
        sl = self._slab(new_owner)
        for c in chunks:
            self.grant[c] = new_owner + 1
            if c not in sl.chunks:
                sl.chunks.append(int(c))
            base = c * cfg.chunk_pages
            for p in range(base, base + cfg.chunk_pages):
                used = log[p, 3] & 1
                if not used:
                    if p not in sl.free:
                        sl.free.append(p)
                    stats["reclaimed"] += 1
                    continue
                stats["used_pages"] += 1
                committed = bool(log[p, 0] & (1 << 30))
                if not committed and log[p, 1] in (OP_INSERT, OP_UPDATE):
                    # redo: re-run the index write for this page (§5.3 c1)
                    ok = self.insert_batch(new_owner,
                                           np.array([log[p, 2]], np.int32),
                                           np.array([p], np.int32),
                                           opcode=int(log[p, 1]))
                    stats["redone"] += int(ok[0])
        return stats

    # --------------------------------------------------------- invariants --
    def check_replicas_converged(self) -> bool:
        idx = np.asarray(self.index)
        return bool((idx[1:] == idx[0]).all()) if self.cfg.replicas > 1 else True
