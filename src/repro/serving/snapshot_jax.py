"""SNAPSHOT replication protocol as a batched, jitted CAS epoch.

The event-level simulator (core/client.py) executes Algorithm 1+2 verb by
verb.  On the serving path the same protocol runs as a *vectorized epoch*:
a batch of W writers (the serving engine's concurrent index updates in one
scheduling tick) all CAS their backup slots, observe the CAS return values
(``v_list``), evaluate the three conflict-resolution rules, and the unique
winner commits the primary — one jitted call, no locks, no serialization,
exactly the paper's collaborative conflict resolution.

Mapping to DM: the replica axis r of ``index`` is the set of memory nodes
holding index replicas (shardable over the mesh's 'model'/pool axis); the
"CAS arrival order" at each replica is an explicit per-replica priority
permutation (the network's nondeterminism, seeded for reproducibility —
property tests sweep seeds).  The atomicity of RDMA_CAS becomes the
atomicity of a scatter-min: each backup slot accepts exactly one writer
per epoch because all writers present the same expected value ``v_old``.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

NO_SLOT = jnp.int32(-1)


class EpochResult(NamedTuple):
    index: jax.Array      # (r, M) updated replicas (flat slots)
    win: jax.Array        # (W,) bool — this writer's value was committed
    committed: jax.Array  # (W,) int32 — value now in the writer's slot
    rule: jax.Array       # (W,) int32 — 1/2/3 for winners, 0 for losers


@partial(jax.jit, static_argnames=())
def snapshot_epoch(index, slot_idx, v_old, v_new, key) -> EpochResult:
    """One SNAPSHOT write round for a batch of writers.

    index: (r, M) int32 flat replicated slots (replica 0 = primary).
    slot_idx: (W,) int32 target slot per writer (-1 = inactive writer).
    v_old: (W,) expected value (what the writer read from the primary).
    v_new: (W,) proposed value (unique per writer by out-of-place alloc).
    key: PRNG key modelling per-replica CAS arrival order.
    """
    r, M = index.shape
    W = slot_idx.shape[0]
    active = slot_idx >= 0
    slot = jnp.where(active, slot_idx, 0)

    cur_primary = index[0, slot]
    # a CAS can only succeed if the expected value matches the *current*
    # replica value; all writers share v_old so each backup slot accepts at
    # most one writer per epoch (RDMA_CAS atomicity).
    can = active & (cur_primary == v_old)

    # per-replica arrival priorities (the network nondeterminism)
    prios = jax.random.uniform(key, (r, W))
    backup_vals = []
    for b in range(1, r):
        valid = can & (index[b, slot] == v_old)
        prio = jnp.where(valid, prios[b], jnp.inf)
        best = jnp.full((M,), jnp.inf).at[slot].min(prio)
        won_cas = valid & (prio == best[slot]) & jnp.isfinite(prio)
        new_b = index[b].at[jnp.where(won_cas, slot, M)].set(
            jnp.where(won_cas, v_new, 0), mode="drop")
        backup_vals.append(new_b)
    new_index = jnp.stack([index[0]] + backup_vals, axis=0) if r > 1 \
        else index

    if r == 1:
        # degenerate single-replica mode: plain CAS race on the primary
        prio = jnp.where(can, prios[0], jnp.inf)
        best = jnp.full((M,), jnp.inf).at[slot].min(prio)
        win = can & (prio == best[slot]) & jnp.isfinite(prio)
        new0 = index[0].at[jnp.where(win, slot, M)].set(
            jnp.where(win, v_new, 0), mode="drop")
        committed = new0[slot]
        return EpochResult(new0[None], win, committed,
                           jnp.where(win, 1, 0).astype(jnp.int32))

    # v_list per writer: the values now in its backup slots (CAS returns)
    v_list = jnp.stack([new_index[b, slot] for b in range(1, r)],
                       axis=1)                         # (W, r-1)
    nb = r - 1
    n_eq = jnp.sum(v_list == v_new[:, None], axis=1)
    rule1 = n_eq == nb
    rule2 = (~rule1) & (2 * n_eq > nb)
    # Rule 3: no majority anywhere -> smallest proposed value wins.  The
    # primary is untouched within an epoch, so the Alg.2 line-12 check
    # (primary still == v_old) always passes for ``can`` writers.
    vmax = jnp.iinfo(jnp.int32).max
    has_any = n_eq > 0
    # a slot is rule-3 eligible only if NO writer on it got a majority
    slot_major = jnp.zeros((M,), bool).at[slot].max(
        jnp.where(can & (rule1 | rule2), True, False))
    vmin_per_slot = jnp.full((M,), vmax).at[slot].min(
        jnp.where(can & has_any,
                  jnp.where(v_list == v_new[:, None], v_new[:, None],
                            vmax).min(axis=1),
                  vmax))
    rule3 = (can & has_any & ~(rule1 | rule2) & ~slot_major[slot]
             & (v_new == vmin_per_slot[slot]))
    win = can & (rule1 | rule2 | rule3)

    # winner commits: repair divergent backups + CAS primary
    wslot = jnp.where(win, slot, M)
    final = new_index.at[:, :].get()
    for b in range(r):
        final = final.at[b, wslot].set(jnp.where(win, v_new, 0), mode="drop")
    committed = final[0, slot]
    rule = jnp.where(rule1, 1, jnp.where(rule2, 2, jnp.where(rule3, 3, 0)))
    return EpochResult(final, win, committed,
                       jnp.where(win, rule, 0).astype(jnp.int32))


def snapshot_epoch_np(index, slot_idx, v_old, v_new, order):
    """Numpy oracle executing the same epoch sequentially (CAS by CAS) in an
    explicit per-replica arrival ``order`` — differentially tested against
    the jitted epoch and against the event-level core simulator."""
    import numpy as np

    index = np.array(index)
    r, M = index.shape
    W = len(slot_idx)
    # phase 2: backup CAS races in arrival order
    for b in range(1, r):
        for w in order[b % len(order)]:
            if slot_idx[w] < 0:
                continue
            s = slot_idx[w]
            if index[0, s] == v_old[w] and index[b, s] == v_old[w]:
                index[b, s] = v_new[w]
    win = np.zeros(W, bool)
    rulev = np.zeros(W, np.int32)
    for w in range(W):
        if slot_idx[w] < 0 or index[0, slot_idx[w]] != v_old[w]:
            continue
        s = slot_idx[w]
        vl = index[1:, s]
        n_eq = int((vl == v_new[w]).sum())
        nb = r - 1
        if nb == 0:
            win[w], rulev[w] = True, 1
            continue
        if n_eq == nb:
            win[w], rulev[w] = True, 1
        elif 2 * n_eq > nb:
            win[w], rulev[w] = True, 2
        elif n_eq > 0:
            # rule 3 candidates: defer; resolved after majority check
            rulev[w] = -3
    # rule 3: per slot, smallest v_new among candidates wins if no majority
    for s in set(int(s) for s in slot_idx if s >= 0):
        cands = [w for w in range(W)
                 if slot_idx[w] == s and rulev[w] == -3]
        if any(win[w] for w in range(W) if slot_idx[w] == s):
            for w in cands:
                rulev[w] = 0
            continue
        if cands:
            wmin = min(cands, key=lambda w: v_new[w])
            win[wmin], rulev[wmin] = True, 3
            for w in cands:
                if w != wmin:
                    rulev[w] = 0
    # winners commit all replicas + primary
    for w in range(W):
        if win[w]:
            index[:, slot_idx[w]] = v_new[w]
    committed = np.array([index[0, s] if s >= 0 else 0 for s in slot_idx])
    return index, win, committed, np.maximum(rulev, 0)
