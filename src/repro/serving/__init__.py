"""FUSEE-managed disaggregated KV-cache serving layer.

The public KV surface is the unified ``core.api.KVStore`` over
``DeviceBackend``; the device pool itself (kvpool.KVPool) is an internal
substrate and is no longer exported here.
"""
from .backend import DeviceBackend  # noqa: F401
from .engine import Request, ServeEngine  # noqa: F401
from .kvpool import PoolConfig  # noqa: F401
from .snapshot_jax import EpochResult, snapshot_epoch, snapshot_epoch_np  # noqa
from . import slots_jax  # noqa: F401
