"""FUSEE-managed disaggregated KV-cache serving layer."""
from .engine import Request, ServeEngine  # noqa: F401
from .kvpool import KVPool, PoolConfig  # noqa: F401
from .snapshot_jax import EpochResult, snapshot_epoch, snapshot_epoch_np  # noqa
from . import slots_jax  # noqa: F401
