"""Device backend for the unified store API (core/api.py).

Maps ``Op`` batches onto the jitted device-resident pool (kvpool.py):
GETs become one batched ``race_lookup`` probe, INSERT/UPDATEs one page
allocation + page write + SNAPSHOT epoch group, DELETEs one epoch — the
batch-native substrate the serving engine runs on.  Futures resolve
eagerly (device ops are synchronous host calls); the surface is identical
to the event-level ``SimBackend``, so the engine, benchmarks, and examples
speak one API for both substrates.

Keys are folded to the pool's 32-bit key space; values (optional, small)
are retained host-side per page so ``get`` round-trips them.  The page id
backing a key is reported on ``OpResult.page`` — the serving engine uses
it for KV-cache page accounting.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Sequence

import numpy as np

from repro.core import codec
from repro.core.api import KVFuture, Op
from repro.core.events import FULL, NOT_FOUND, OK, OpResult
from repro.core.faults import ClientCrashed

from .kvpool import KVPool, OP_INSERT, OP_UPDATE, PoolConfig


def _key32(key) -> int:
    k = codec.encode_key(key)
    k = (k ^ (k >> 32)) & 0x7FFFFFFF
    return k if k != 0 else 1


class DeviceBackend:
    """Batch-native backend over the device-resident FUSEE pool."""

    def __init__(self, cfg: Optional[PoolConfig] = None, *, cid: int = 0,
                 pool: Optional[KVPool] = None, seed: int = 0):
        self.pool = pool if pool is not None else KVPool(cfg or PoolConfig(),
                                                         seed=seed)
        self.cid = cid
        self.crashed = False                 # set by ServeEngine.crash_worker
        self._values: Dict[int, Any] = {}    # page -> encoded value words
        # ordered keydir twin (core/ordered.py on the sim substrate): the
        # key32s this backend has upserted, kept as a *superset* of the
        # live set — scans validate every candidate against the device
        # index (one batched race_lookup probe), so spurious members are
        # filtered exactly like stale ordered entries in the simulator
        self._keydir: set = set()

    # ------------------------------------------------------------- submit
    def submit_many(self, ops: Sequence[Op]) -> List[KVFuture]:
        if self.crashed:
            # same typed error as the event-level substrate: one failure
            # surface across both backends
            raise ClientCrashed(self.cid)
        futs = [KVFuture(self) for _ in ops]
        # execute maximal same-kind runs as one device batch, preserving
        # cross-kind program order
        i = 0
        while i < len(ops):
            j = i
            while j < len(ops) and ops[j].kind == ops[i].kind:
                j += 1
            self._exec_group(ops[i].kind, list(range(i, j)), ops, futs)
            i = j
        return futs

    def _exec_group(self, kind: str, idxs: List[int], ops, futs):
        if kind == "search":
            keys = np.array([_key32(ops[i].key) for i in idxs], np.int32)
            ptr, found = self.pool.search(keys)
            for n, i in enumerate(idxs):
                if found[n]:
                    page = int(ptr[n])
                    futs[i]._resolve(OpResult(OK, page=page,
                                              value=self._values.get(page)))
                else:
                    futs[i]._resolve(OpResult(NOT_FOUND))
        elif kind in ("insert", "update"):
            # Duplicate keys within one batch are concurrent upserts of the
            # same key: exactly one page is written (last writer's value
            # wins) and every duplicate resolves to that one result — the
            # pool would otherwise supersede-and-free a page whose OK
            # future the caller still holds.
            first: Dict[int, int] = {}      # key32 -> position of its op
            for n, i in enumerate(idxs):
                first[_key32(ops[i].key)] = n
            uniq = sorted(first.values())
            keys = np.array([_key32(ops[idxs[n]].key) for n in uniq],
                            np.int32)
            pages = self.pool.alloc_pages(self.cid, len(uniq))
            if (pages < 0).any() and self.pool.reclaim(self.cid):
                # slab ran dry but bitmap-freed pages (superseded upserts,
                # released surplus) were reclaimable: retry the dead slots
                dead = pages < 0
                pages[dead] = self.pool.alloc_pages(self.cid,
                                                    int(dead.sum()))
            live = pages >= 0
            if live.any():
                opcode = OP_INSERT if kind == "insert" else OP_UPDATE
                self.pool.write_pages(self.cid, pages[live], keys[live],
                                      opcode=opcode)
                ok = self.pool.insert_batch(self.cid, keys[live], pages[live],
                                            opcode=opcode)
            else:
                ok = np.zeros(0, bool)
            results: Dict[int, OpResult] = {}
            k = 0
            for m, n in enumerate(uniq):
                key = int(keys[m])
                if not live[m]:
                    results[key] = OpResult(FULL, page=-1)
                    continue
                page = int(pages[m])
                won = bool(ok[k]); k += 1
                self._values[page] = codec.encode_value(ops[idxs[n]].value)
                # keydir superset: even a lost upsert means the KEY is
                # live (another page won its slot); scans validate
                self._keydir.add(key)
                results[key] = OpResult(OK if won else FULL, page=page,
                                        value=self._values[page])
            for i in idxs:
                futs[i]._resolve(results[_key32(ops[i].key)])
        elif kind == "delete":
            keys = np.array([_key32(ops[i].key) for i in idxs], np.int32)
            ok = self.pool.delete_batch(self.cid, keys)
            for n, i in enumerate(idxs):
                if ok[n]:
                    self._keydir.discard(int(keys[n]))
                futs[i]._resolve(OpResult(OK if ok[n] else NOT_FOUND))
        elif kind in ("scan", "range"):
            for i in idxs:
                futs[i]._resolve(self._scan_one(ops[i]))
        elif kind == "reclaim":
            n = self.pool.reclaim(self.cid)
            for i in idxs:
                futs[i]._resolve(OpResult(OK, value=[n]))
        else:
            raise ValueError(kind)

    # ------------------------------------------------------ ordered scan
    def _scan_one(self, op: Op) -> OpResult:
        """SCAN/RANGE on the device substrate: locate the start position
        in the sorted keydir via the shared ``leaf_probe`` entry point,
        validate the candidate window against the device index with one
        batched ``race_lookup`` probe, and return ``[(key32, value),
        ...]`` in key order — the serving twin of core/ordered.py."""
        from repro.core.ordered import leaf_probe_np
        start = _key32(op.key)
        if op.kind == "scan":
            count, end = int(op.value), None
        else:
            count, end = None, _key32(op.value)
        keys = np.array(sorted(self._keydir), np.uint64)
        if not len(keys):
            return OpResult(OK, value=[])
        try:                              # Pallas on TPU, numpy elsewhere
            from repro.kernels import leaf_probe_batch as _probe
        except Exception:                 # pragma: no cover - jax-less env
            _probe = leaf_probe_np
        pos = int(_probe(np.array([start], np.uint64), keys)[0])
        first = pos if (pos >= 0 and int(keys[pos]) >= start) else pos + 1
        cands = keys[first:]
        if end is not None:
            cands = cands[cands < np.uint64(end)]
        out: list = []
        i = 0
        while i < len(cands) and (count is None or len(out) < count):
            window = cands[i:i + max(2 * (count or 64), 64)]
            ptr, found = self.pool.search(window.astype(np.int64)
                                          .astype(np.int32))
            for n, k in enumerate(window.tolist()):
                if found[n]:
                    out.append((int(k), self._values.get(int(ptr[n]))))
                    if count is not None and len(out) >= count:
                        break
            i += len(window)
        return OpResult(OK, value=out)

    # --------------------------------------------------- page management
    def release_pages(self, pages: np.ndarray):
        """Free surplus pages (index no longer references them) back to the
        pool's free bitmap — the engine's retire path."""
        pages = np.asarray(pages, np.int32)
        if len(pages):
            self.pool.free_pages(pages)
            for p in pages.tolist():
                self._values.pop(int(p), None)

    # ------------------------------------------------------------- driving
    def drive(self, fut: KVFuture):     # futures resolve eagerly
        if not fut.done():              # pragma: no cover - defensive
            raise RuntimeError("device future left unresolved")

    def drain(self):
        pass

    # ---------------------------------------------------------------- stats
    def stats(self) -> Dict[str, Any]:
        return {"backend": "device", "cid": self.cid, "inflight": 0,
                "crashed": self.crashed,
                "pages_valued": len(self._values), **self.pool.stats}
