"""JAX twin of the FUSEE slot/metadata layout, 32-bit serving variant.

The event-level simulator (core/layout.py) uses the paper's 64-bit slots.
The serving pool works in a smaller address space — a slot names a *page*
in the on-device KV pool — so slots are uint32-as-int32 words:

    | fp : 8 | page_ptr : 24 |          (fp 0 reserved = empty)

Hashing is the xorshift-multiply hash32 shared with the race_lookup Pallas
kernel (kernels/race_lookup/ref.py); packing is differentially tested
against a numpy mirror.  All arrays are int32 (JAX default-int friendly);
bit games rely on wrap-around int32 arithmetic which JAX guarantees.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.race_lookup.ref import (MASK24, bucket_pair, fingerprint,
                                           hash32)

__all__ = ["MASK24", "bucket_pair", "fingerprint", "hash32", "pack_slot",
           "slot_fp", "slot_ptr", "pack_slot_np", "slot_fp_np", "slot_ptr_np"]


def pack_slot(fp, ptr):
    """fp (…,) int32 in [1,255]; ptr (…,) int32 in [0, 2^24)."""
    return ((fp.astype(jnp.uint32) << 24)
            | (ptr.astype(jnp.uint32) & MASK24)).astype(jnp.int32)


def slot_fp(slot):
    return ((slot.astype(jnp.uint32) >> 24) & 0xFF).astype(jnp.int32)


def slot_ptr(slot):
    return (slot & MASK24).astype(jnp.int32)


# numpy mirrors (differential tests)
def pack_slot_np(fp, ptr):
    return ((np.uint32(fp) << np.uint32(24))
            | (np.uint32(ptr) & np.uint32(MASK24))).astype(np.uint32) \
        .view(np.int32)


def slot_fp_np(slot):
    return ((np.asarray(slot).view(np.uint32) >> 24) & 0xFF).astype(np.int32)


def slot_ptr_np(slot):
    return (np.asarray(slot).view(np.uint32) & np.uint32(MASK24)) \
        .astype(np.int32)
